"""Model assembly for the assigned architecture pool.

Families: dense (GQA or MLA), moe, ssm (Mamba2), hybrid (Zamba2-style),
encdec (Whisper-style), vlm (Llama-3.2-Vision-style).

Conventions:
  * scan-over-layers everywhere — per-layer params carry a leading
    ``layers`` dim, so the HLO stays one-layer-sized regardless of depth and
    GSPMD pipelines layer i+1's FSDP all-gather against layer i's compute;
  * forward(..) is the shared body; ``train_loss`` adds next-token CE;
    ``prefill`` additionally returns the KV/SSM cache; ``decode_step``
    advances one token.
  * the modality frontends of [audio]/[vlm] archs are STUBS per the harness:
    the batch provides precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.params import ParamInfo, abstract_params, init_params
from repro.utils.config import ModelConfig


# ----------------------------------------------------------------------
# helpers
# ----------------------------------------------------------------------
def stack_infos(tree, n: int, axis_name: str = "layers"):
    return jax.tree_util.tree_map(
        lambda i: ParamInfo((n,) + i.shape, (axis_name,) + i.logical,
                            i.dtype, i.init, i.scale),
        tree, is_leaf=lambda x: isinstance(x, ParamInfo))




def _scan_u(*args, **kw):
    """lax.scan that honours the cost-compile unroll flag (outer scans)."""
    kw.setdefault("unroll", _iu())
    return jax.lax.scan(*args, **kw)

def _iu():
    """Inner-scan unroll flag (see layers.set_inner_unroll) — cost compiles
    fully unroll nested layer-group scans so XLA counts every iteration."""
    from repro.models.layers import INNER_SCAN_UNROLL
    return INNER_SCAN_UNROLL or 1

def _remat(fn, cfg: ModelConfig):
    """Layer-scan remat policy.

    'dots' (the default; name kept for config compat) saves ONLY tensors
    tagged ``blk_out`` — the [B,S,D] block outputs.  A literal
    checkpoint_dots policy would save every attention-score / SSD-score dot
    across the layer scan (hundreds of GB at 32k context); block outputs are
    the classic activation-checkpointing residual set.
    """
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.save_only_these_names("blk_out")
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)          # "full": save nothing


def _tag(x):
    from jax.ad_checkpoint import checkpoint_name
    return checkpoint_name(x, "blk_out")


# §Perf knob: sequence-parallel sharding of saved activations (the layer-scan
# carry).  ON keeps remat residuals 1/model-axis smaller at the cost of
# per-layer all-gathers; OFF trades memory for collectives.  The perf harness
# flips this per-cell to find each arch's better side.
SEQ_SHARD_ACTS = True


def set_seq_shard_acts(flag: bool) -> None:
    global SEQ_SHARD_ACTS
    SEQ_SHARD_ACTS = bool(flag)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean next-token CE in fp32.  logits [B,S,V], labels [B,S]."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - gold)


# ----------------------------------------------------------------------
# per-layer block bodies
# ----------------------------------------------------------------------
def _dense_layer_infos(cfg: ModelConfig) -> Dict[str, Any]:
    attn = L.mla_infos(cfg) if cfg.use_mla else L.gqa_infos(cfg)
    return {"ln1": L.rmsnorm_info(cfg.d_model),
            "attn": attn,
            "ln2": L.rmsnorm_info(cfg.d_model),
            "mlp": L.swiglu_infos(cfg)}


def _dense_layer(p, x, cfg: ModelConfig, *, kv_chunk=2048):
    h = L.rmsnorm(x, p["ln1"])
    if cfg.use_mla:
        a = L.mla_attention(p["attn"], h, cfg, kv_chunk=kv_chunk)
    else:
        a = L.gqa_attention(p["attn"], h, cfg, causal=True, kv_chunk=kv_chunk)
    x = x + _tag(a)
    return x + _tag(L.swiglu(p["mlp"], L.rmsnorm(x, p["ln2"])))


def _moe_layer_infos(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln1": L.rmsnorm_info(cfg.d_model),
            "attn": L.gqa_infos(cfg),
            "ln2": L.rmsnorm_info(cfg.d_model),
            "moe": MOE.moe_infos(cfg)}


def _ssm_layer_infos(cfg: ModelConfig) -> Dict[str, Any]:
    return {"ln": L.rmsnorm_info(cfg.d_model), "ssm": SSM.ssm_infos(cfg)}


# ----------------------------------------------------------------------
# the Model object
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    mesh: Any = None                       # optional: enables shard_map MoE
    batch_axes: Tuple[str, ...] = ("data",)

    # ---------------- parameter trees ----------------
    def infos(self):
        cfg = self.cfg
        base = {"embed": L.embedding_infos(cfg)}
        if cfg.family in ("dense",):
            base["layers"] = stack_infos(_dense_layer_infos(cfg), cfg.num_layers)
        elif cfg.family == "moe":
            base["layers"] = stack_infos(_moe_layer_infos(cfg), cfg.num_layers)
        elif cfg.family == "ssm":
            base["layers"] = stack_infos(_ssm_layer_infos(cfg), cfg.num_layers)
        elif cfg.family == "hybrid":
            groups = cfg.num_layers // cfg.hybrid_attn_every
            per_group = stack_infos(_ssm_layer_infos(cfg), cfg.hybrid_attn_every)
            base["layers"] = stack_infos(per_group, groups)
            base["shared_attn"] = {"ln1": L.rmsnorm_info(cfg.d_model),
                                   "attn": L.gqa_infos(cfg),
                                   "ln2": L.rmsnorm_info(cfg.d_model),
                                   "mlp": L.swiglu_infos(cfg)}
        elif cfg.family == "encdec":
            enc_layer = {"ln1": L.rmsnorm_info(cfg.d_model),
                         "attn": L.gqa_infos(cfg),
                         "ln2": L.rmsnorm_info(cfg.d_model),
                         "mlp": L.swiglu_infos(cfg)}
            dec_layer = {"ln1": L.rmsnorm_info(cfg.d_model),
                         "self_attn": L.gqa_infos(cfg),
                         "ln_x": L.rmsnorm_info(cfg.d_model),
                         "cross_attn": L.gqa_infos(cfg),
                         "ln2": L.rmsnorm_info(cfg.d_model),
                         "mlp": L.swiglu_infos(cfg)}
            base["encoder"] = stack_infos(enc_layer, cfg.num_encoder_layers)
            base["enc_norm"] = L.rmsnorm_info(cfg.d_model)
            base["layers"] = stack_infos(dec_layer, cfg.num_layers)
        elif cfg.family == "vlm":
            groups = cfg.num_layers // (cfg.cross_attn_every)
            self_per_group = cfg.cross_attn_every - 1
            self_layer = _dense_layer_infos(cfg)
            cross_layer = {"ln1": L.rmsnorm_info(cfg.d_model),
                           "attn": L.gqa_infos(cfg),
                           "gate": ParamInfo((1,), (None,), init="zeros",
                                             dtype=jnp.float32),
                           "ln2": L.rmsnorm_info(cfg.d_model),
                           "mlp": L.swiglu_infos(cfg)}
            base["layers"] = stack_infos(stack_infos(self_layer, self_per_group),
                                         groups)
            base["cross_layers"] = stack_infos(cross_layer, groups)
        else:
            raise ValueError(f"unknown family {cfg.family!r}")
        return base

    def init(self, key: jax.Array):
        return init_params(self.infos(), key)

    def abstract(self):
        return abstract_params(self.infos())

    # ---------------- forward bodies ----------------
    def _moe_apply(self, p, x):
        return MOE.moe_apply(p, x, self.cfg, mesh=self.mesh,
                             batch_axes=self.batch_axes)

    def constrain_acts(self, x):
        """Sequence-parallel sharding constraint for the layer-scan carry.

        Saved activations (the remat residual set) shard over BOTH the batch
        axes and the model axis (sequence dim) — without this, an 88-layer
        arch at 4k context saves an unsharded [B,S,D] per layer and blows
        HBM.  GSPMD inserts the all-gather before attention and the
        reduce-scatter after (Korthikanti-style sequence parallelism).
        """
        if self.mesh is None or x.ndim != 3 or not SEQ_SHARD_ACTS:
            return x
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        b, s, _ = x.shape
        nb = int(np.prod([sizes[a] for a in self.batch_axes]))
        bspec = self.batch_axes if b % nb == 0 else None
        sspec = "model" if (s > 1 and s % sizes["model"] == 0) else None
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, PS(bspec, sspec, None)))

    def constrain_kv(self, x):
        """Cache-layout constraint for prefill-produced K/V ([B,S,KV,hd]) or
        MLA latents ([B,S,W]).  Must be applied INSIDE the layer scan —
        constraining only at the jit output boundary leaves full-sequence
        stacks live across the scan (tens of GB at 32k prefill)."""
        if self.mesh is None or x.ndim not in (3, 4):
            return x
        import numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as PS
        sizes = dict(zip(self.mesh.axis_names, self.mesh.devices.shape))
        b, s = x.shape[0], x.shape[1]
        nb = int(np.prod([sizes[a] for a in self.batch_axes]))
        bspec = self.batch_axes if b % nb == 0 else None
        if x.ndim == 4 and x.shape[2] % sizes["model"] == 0:
            spec = PS(bspec, None, "model", None)          # kv-heads sharded
        elif s % sizes["model"] == 0:
            spec = PS(bspec, "model", *([None] * (x.ndim - 2)))  # seq sharded
        else:
            spec = PS(bspec, *([None] * (x.ndim - 1)))
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, spec))

    def _backbone(self, params, x, *, kv_chunk=2048, img=None):
        """Token stream through the stacked layers (no embed/unembed)."""
        cfg = self.cfg

        if cfg.family == "dense":
            def body(h, lp):
                h = self.constrain_acts(h)
                return _dense_layer(lp, h, cfg, kv_chunk=kv_chunk), None
            x, _ = _scan_u(_remat(body, cfg), x, params["layers"])
            return x

        if cfg.family == "moe":
            def body(h, lp):
                h = self.constrain_acts(h)
                a = L.gqa_attention(lp["attn"], L.rmsnorm(h, lp["ln1"]),
                                    cfg, causal=True, kv_chunk=kv_chunk)
                h = h + _tag(a)
                return h + _tag(self._moe_apply(lp["moe"],
                                                L.rmsnorm(h, lp["ln2"]))), None
            x, _ = _scan_u(_remat(body, cfg), x, params["layers"])
            return x

        if cfg.family == "ssm":
            def body(h, lp):
                h = self.constrain_acts(h)
                return h + _tag(SSM.ssd_forward(
                    lp["ssm"], L.rmsnorm(h, lp["ln"]), cfg)), None
            x, _ = _scan_u(_remat(body, cfg), x, params["layers"])
            return x

        if cfg.family == "hybrid":
            shared = params["shared_attn"]

            def inner(h, lp):
                return h + _tag(SSM.ssd_forward(
                    lp["ssm"], L.rmsnorm(h, lp["ln"]), cfg)), None

            def group(h, gp):
                h = self.constrain_acts(h)
                h, _ = jax.lax.scan(inner, h, gp, unroll=_iu())
                a = L.gqa_attention(shared["attn"], L.rmsnorm(h, shared["ln1"]),
                                    cfg, causal=True, kv_chunk=kv_chunk)
                h = h + _tag(a)
                h = h + _tag(L.swiglu(shared["mlp"],
                                      L.rmsnorm(h, shared["ln2"])))
                return h, None

            x, _ = _scan_u(_remat(group, cfg), x, params["layers"])
            return x

        if cfg.family == "vlm":
            def group(h, gps):
                h = self.constrain_acts(h)
                gp, cp = gps
                def inner(hh, lp):
                    return _dense_layer(lp, hh, cfg, kv_chunk=kv_chunk), None
                h, _ = jax.lax.scan(inner, h, gp, unroll=_iu())
                # gated cross-attention onto the (stub) image embeddings
                k = jnp.einsum("bsd,dkh->bskh", img, cp["attn"]["wk"])
                v = jnp.einsum("bsd,dkh->bskh", img, cp["attn"]["wv"])
                a = L.gqa_attention(cp["attn"], L.rmsnorm(h, cp["ln1"]), cfg,
                                    causal=False, kv_override=(k, v),
                                    kv_chunk=kv_chunk)
                h = h + _tag(jnp.tanh(cp["gate"]).astype(h.dtype) * a)
                h = h + _tag(L.swiglu(cp["mlp"], L.rmsnorm(h, cp["ln2"])))
                return h, None

            x, _ = _scan_u(_remat(group, cfg), x,
                                (params["layers"], params["cross_layers"]))
            return x

        raise ValueError(cfg.family)

    def _encode(self, params, frames, *, kv_chunk=2048):
        """Whisper encoder over stub frame embeddings [B, S_enc, D]."""
        cfg = self.cfg

        def body(h, lp):
            h = self.constrain_acts(h)
            a = L.gqa_attention(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                                causal=False, kv_chunk=kv_chunk)
            h = h + _tag(a)
            return h + _tag(L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))), None

        h, _ = _scan_u(_remat(body, cfg), frames, params["encoder"])
        return L.rmsnorm(h, params["enc_norm"])

    def _decoder(self, params, x, enc, *, kv_chunk=2048):
        cfg = self.cfg

        def body(h, lp):
            h = self.constrain_acts(h)
            a = L.gqa_attention(lp["self_attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                                causal=True, kv_chunk=kv_chunk)
            h = h + _tag(a)
            k = jnp.einsum("bsd,dkh->bskh", enc, lp["cross_attn"]["wk"])
            v = jnp.einsum("bsd,dkh->bskh", enc, lp["cross_attn"]["wv"])
            c = L.gqa_attention(lp["cross_attn"], L.rmsnorm(h, lp["ln_x"]),
                                cfg, causal=False, kv_override=(k, v),
                                kv_chunk=kv_chunk)
            h = h + _tag(c)
            return h + _tag(L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))), None

        h, _ = _scan_u(_remat(body, cfg), x, params["layers"])
        return h

    # ---------------- public entry points ----------------
    def forward(self, params, batch: Dict[str, jnp.ndarray], *,
                kv_chunk: int = 2048) -> jnp.ndarray:
        """Logits [B, S, V] for a full sequence (train / eval)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = L.embed(params["embed"], tokens)
        if cfg.family == "encdec":
            enc = self._encode(params, batch["frames"], kv_chunk=kv_chunk)
            x = self._decoder(params, x, enc, kv_chunk=kv_chunk)
        elif cfg.family == "vlm":
            x = self._backbone(params, x, kv_chunk=kv_chunk,
                               img=batch["image_embeds"])
        else:
            x = self._backbone(params, x, kv_chunk=kv_chunk)
        return L.unembed(params["embed"], x)

    def train_loss(self, params, batch, *, kv_chunk: int = 2048) -> jnp.ndarray:
        """Next-token CE.  batch['tokens'] is [B, S+1]."""
        inp = {**batch, "tokens": batch["tokens"][:, :-1]}
        logits = self.forward(params, inp, kv_chunk=kv_chunk)
        return cross_entropy(logits, batch["tokens"][:, 1:])
