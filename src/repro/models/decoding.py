"""KV/SSM caches, prefill and single-token decode for every family.

Cache layouts (all leading-``layers``-stacked so decode scans over them):
  dense-GQA / moe : k, v   [L, B, S_max, KV, hd]
  dense-MLA       : ckv    [L, B, S_max, kv_lora + rope]      (compressed)
  ssm             : h [L, B, H, hd, N] fp32; conv [L, B, 3, C]
  hybrid          : per-group ssm states + shared-attn caches [G, B, S, KV, hd]
  encdec          : decoder self k/v + precomputed cross k/v over enc states
  vlm             : per-group self k/v + precomputed cross k/v over patches

``cache_len`` is a scalar int32 carried in the cache dict; decode writes at
that position and masks validity with it (static shapes, GSPMD-friendly
dynamic_update_slice).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.model import Model
from repro.utils.config import ModelConfig




def _scan_u(*args, **kw):
    """lax.scan that honours the cost-compile unroll flag (outer scans)."""
    kw.setdefault("unroll", _iu())
    return jax.lax.scan(*args, **kw)

def _iu():
    from repro.models.layers import INNER_SCAN_UNROLL
    return INNER_SCAN_UNROLL or 1


# ----------------------------------------------------------------------
# cache construction
# ----------------------------------------------------------------------
def cache_shapes(cfg: ModelConfig, batch: int, max_len: int,
                 enc_len: int = 0, img_len: int = 0) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the decode cache (dry-run + init)."""
    dt = jnp.bfloat16
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    sds = jax.ShapeDtypeStruct

    if cfg.family in ("dense", "moe") and not cfg.use_mla:
        return {"k": sds((cfg.num_layers, batch, max_len, kv, hd), dt),
                "v": sds((cfg.num_layers, batch, max_len, kv, hd), dt),
                "len": sds((), jnp.int32)}
    if cfg.use_mla:
        width = cfg.kv_lora_rank + cfg.qk_rope_head_dim
        return {"ckv": sds((cfg.num_layers, batch, max_len, width), dt),
                "len": sds((), jnp.int32)}
    if cfg.family == "ssm":
        d_in, h, n = SSM.ssm_dims(cfg)
        conv_ch = d_in + 2 * n
        return {"h": sds((cfg.num_layers, batch, h, cfg.ssm_head_dim, n),
                         jnp.float32),
                "conv": sds((cfg.num_layers, batch, SSM.CONV_W - 1, conv_ch), dt),
                "len": sds((), jnp.int32)}
    if cfg.family == "hybrid":
        groups = cfg.num_layers // cfg.hybrid_attn_every
        per = cfg.hybrid_attn_every
        d_in, h, n = SSM.ssm_dims(cfg)
        conv_ch = d_in + 2 * n
        return {"h": sds((groups, per, batch, h, cfg.ssm_head_dim, n),
                         jnp.float32),
                "conv": sds((groups, per, batch, SSM.CONV_W - 1, conv_ch), dt),
                "k": sds((groups, batch, max_len, kv, hd), dt),
                "v": sds((groups, batch, max_len, kv, hd), dt),
                "len": sds((), jnp.int32)}
    if cfg.family == "encdec":
        return {"k": sds((cfg.num_layers, batch, max_len, kv, hd), dt),
                "v": sds((cfg.num_layers, batch, max_len, kv, hd), dt),
                "xk": sds((cfg.num_layers, batch, enc_len, kv, hd), dt),
                "xv": sds((cfg.num_layers, batch, enc_len, kv, hd), dt),
                "len": sds((), jnp.int32)}
    if cfg.family == "vlm":
        groups = cfg.num_layers // cfg.cross_attn_every
        spg = cfg.cross_attn_every - 1
        return {"k": sds((groups, spg, batch, max_len, kv, hd), dt),
                "v": sds((groups, spg, batch, max_len, kv, hd), dt),
                "xk": sds((groups, batch, img_len, kv, hd), dt),
                "xv": sds((groups, batch, img_len, kv, hd), dt),
                "len": sds((), jnp.int32)}
    raise ValueError(cfg.family)


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               enc_len: int = 0, img_len: int = 0):
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        cache_shapes(cfg, batch, max_len, enc_len, img_len))


# ----------------------------------------------------------------------
# decode step
# ----------------------------------------------------------------------
def decode_step(model: Model, params, cache: Dict[str, Any],
                token: jnp.ndarray) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """One-token decode.  token: [B, 1] int32 → (logits [B, 1, V], cache')."""
    cfg = model.cfg
    x = L.embed(params["embed"], token)
    clen = cache["len"]

    if cfg.family in ("dense", "moe") and not cfg.use_mla:
        def body(h, xs):
            lp, ck, cv = xs
            a, nk, nv = L.gqa_decode(lp["attn"], L.rmsnorm(h, lp["ln1"]),
                                     ck, cv, clen, cfg)
            h = h + a
            hn = L.rmsnorm(h, lp["ln2"])
            if cfg.family == "moe":
                h = h + model._moe_apply(lp["moe"], hn)
            else:
                h = h + L.swiglu(lp["mlp"], hn)
            return h, (nk, nv)

        x, (nk, nv) = _scan_u(body, x,
                                   (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": nk, "v": nv, "len": clen + 1}

    elif cfg.use_mla:
        def body(h, xs):
            lp, ckv = xs
            a, nckv = L.mla_decode(lp["attn"], L.rmsnorm(h, lp["ln1"]),
                                   ckv, clen, cfg)
            h = h + a
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, nckv

        x, nckv = _scan_u(body, x, (params["layers"], cache["ckv"]))
        new_cache = {"ckv": nckv, "len": clen + 1}

    elif cfg.family == "ssm":
        def body(h, xs):
            lp, hs, cs = xs
            out, st = SSM.ssd_decode(lp["ssm"], L.rmsnorm(h, lp["ln"]),
                                     SSM.SSMState(hs, cs), cfg)
            return h + out, (st.h, st.conv)

        x, (nh, nc) = _scan_u(body, x,
                                   (params["layers"], cache["h"], cache["conv"]))
        new_cache = {"h": nh, "conv": nc, "len": clen + 1}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def inner(h, xs):
            lp, hs, cs = xs
            out, st = SSM.ssd_decode(lp["ssm"], L.rmsnorm(h, lp["ln"]),
                                     SSM.SSMState(hs, cs), cfg)
            return h + out, (st.h, st.conv)

        def group(h, xs):
            gp, hs, cs, ck, cv = xs
            h, (nh, ncv) = _scan_u(inner, h, (gp, hs, cs),
                                        unroll=_iu())
            a, nk, nv = L.gqa_decode(shared["attn"],
                                     L.rmsnorm(h, shared["ln1"]),
                                     ck, cv, clen, cfg)
            h = h + a
            h = h + L.swiglu(shared["mlp"], L.rmsnorm(h, shared["ln2"]))
            return h, (nh, ncv, nk, nv)

        x, (nh, nc, nk, nv) = _scan_u(
            group, x, (params["layers"], cache["h"], cache["conv"],
                       cache["k"], cache["v"]))
        new_cache = {"h": nh, "conv": nc, "k": nk, "v": nv, "len": clen + 1}

    elif cfg.family == "encdec":
        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            a, nk, nv = L.gqa_decode(lp["self_attn"],
                                     L.rmsnorm(h, lp["ln1"]), ck, cv, clen, cfg)
            h = h + a
            c = L.gqa_attention(lp["cross_attn"], L.rmsnorm(h, lp["ln_x"]),
                                cfg, causal=False, kv_override=(xk, xv),
                                kv_chunk=xk.shape[1])
            h = h + c
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, (nk, nv)

        x, (nk, nv) = _scan_u(
            body, x, (params["layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        new_cache = {**cache, "k": nk, "v": nv, "len": clen + 1}

    elif cfg.family == "vlm":
        def inner(h, xs):
            lp, ck, cv = xs
            a, nk, nv = L.gqa_decode(lp["attn"], L.rmsnorm(h, lp["ln1"]),
                                     ck, cv, clen, cfg)
            h = h + a
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, (nk, nv)

        def group(h, xs):
            gp, cp, ck, cv, xk, xv = xs
            h, (nk, nv) = _scan_u(inner, h, (gp, ck, cv),
                                       unroll=_iu())
            a = L.gqa_attention(cp["attn"], L.rmsnorm(h, cp["ln1"]), cfg,
                                causal=False, kv_override=(xk, xv),
                                kv_chunk=xk.shape[1])
            h = h + jnp.tanh(cp["gate"]).astype(h.dtype) * a
            h = h + L.swiglu(cp["mlp"], L.rmsnorm(h, cp["ln2"]))
            return h, (nk, nv)

        x, (nk, nv) = _scan_u(
            group, x, (params["layers"], params["cross_layers"],
                       cache["k"], cache["v"], cache["xk"], cache["xv"]))
        new_cache = {**cache, "k": nk, "v": nv, "len": clen + 1}

    else:
        raise ValueError(cfg.family)

    logits = L.unembed(params["embed"], x)
    return logits, new_cache


# ----------------------------------------------------------------------
# prefill
# ----------------------------------------------------------------------
def prefill(model: Model, params, batch: Dict[str, jnp.ndarray], *,
            max_len: int = 0, kv_chunk: int = 2048
            ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """Process the prompt, returning (logits [B, S, V], cache at len S)."""
    cfg = model.cfg
    tokens = batch["tokens"]
    b, s = tokens.shape
    max_len = max(max_len, s)
    x = L.embed(params["embed"], tokens)

    def pad_seq(t):
        if max_len == s:
            return t
        return jnp.pad(t, ((0, 0), (0, max_len - s)) + ((0, 0),) * (t.ndim - 2))

    if cfg.family in ("dense", "moe") and not cfg.use_mla:
        def body(h, lp):
            h = model.constrain_acts(h)
            a, k, v = L.gqa_prefill(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                                    kv_chunk=kv_chunk)
            h = h + a
            hn = L.rmsnorm(h, lp["ln2"])
            if cfg.family == "moe":
                h = h + model._moe_apply(lp["moe"], hn)
            else:
                h = h + L.swiglu(lp["mlp"], hn)
            return h, (model.constrain_kv(pad_seq(k)),
                       model.constrain_kv(pad_seq(v)))

        x, (ks, vs) = _scan_u(body, x, params["layers"])
        cache = {"k": ks, "v": vs, "len": jnp.int32(s)}

    elif cfg.use_mla:
        def body(h, lp):
            h = model.constrain_acts(h)
            a, ckv = L.mla_prefill(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                                   kv_chunk=kv_chunk)
            h = h + a
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, model.constrain_kv(pad_seq(ckv))

        x, ckvs = _scan_u(body, x, params["layers"])
        cache = {"ckv": ckvs, "len": jnp.int32(s)}

    elif cfg.family == "ssm":
        def body(h, lp):
            h = model.constrain_acts(h)
            y, st = SSM.ssd_forward_with_state(
                lp["ssm"], L.rmsnorm(h, lp["ln"]), cfg)
            return h + y, (st.h, st.conv)

        x, (hs, cs) = _scan_u(body, x, params["layers"])
        cache = {"h": hs, "conv": cs, "len": jnp.int32(s)}

    elif cfg.family == "hybrid":
        shared = params["shared_attn"]

        def inner(h, lp):
            y, st = SSM.ssd_forward_with_state(
                lp["ssm"], L.rmsnorm(h, lp["ln"]), cfg)
            return h + y, (st.h, st.conv)

        def group(h, gp):
            h = model.constrain_acts(h)
            h, (hs, cs) = jax.lax.scan(inner, h, gp, unroll=_iu())
            a, k, v = L.gqa_prefill(shared["attn"], L.rmsnorm(h, shared["ln1"]),
                                    cfg, kv_chunk=kv_chunk)
            h = h + a
            h = h + L.swiglu(shared["mlp"], L.rmsnorm(h, shared["ln2"]))
            return h, (hs, cs, model.constrain_kv(pad_seq(k)),
                       model.constrain_kv(pad_seq(v)))

        x, (hs, cs, ks, vs) = _scan_u(group, x, params["layers"])
        cache = {"h": hs, "conv": cs, "k": ks, "v": vs, "len": jnp.int32(s)}

    elif cfg.family == "encdec":
        enc = model._encode(params, batch["frames"], kv_chunk=kv_chunk)

        def body(h, lp):
            h = model.constrain_acts(h)
            a, k, v = L.gqa_prefill(lp["self_attn"], L.rmsnorm(h, lp["ln1"]),
                                    cfg, kv_chunk=kv_chunk)
            h = h + a
            xk = jnp.einsum("bsd,dkh->bskh", enc, lp["cross_attn"]["wk"])
            xv = jnp.einsum("bsd,dkh->bskh", enc, lp["cross_attn"]["wv"])
            c = L.gqa_attention(lp["cross_attn"], L.rmsnorm(h, lp["ln_x"]),
                                cfg, causal=False, kv_override=(xk, xv),
                                kv_chunk=kv_chunk)
            h = h + c
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, (model.constrain_kv(pad_seq(k)),
                       model.constrain_kv(pad_seq(v)),
                       model.constrain_kv(xk),
                       model.constrain_kv(xv))

        x, (ks, vs, xks, xvs) = _scan_u(body, x, params["layers"])
        cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "len": jnp.int32(s)}

    elif cfg.family == "vlm":
        img = batch["image_embeds"]

        def inner(h, lp):
            a, k, v = L.gqa_prefill(lp["attn"], L.rmsnorm(h, lp["ln1"]), cfg,
                                    kv_chunk=kv_chunk)
            h = h + a
            h = h + L.swiglu(lp["mlp"], L.rmsnorm(h, lp["ln2"]))
            return h, (model.constrain_kv(pad_seq(k)),
                       model.constrain_kv(pad_seq(v)))

        def group(h, xs):
            gp, cp = xs
            h = model.constrain_acts(h)
            h, (ks, vs) = jax.lax.scan(inner, h, gp, unroll=_iu())
            xk = jnp.einsum("bsd,dkh->bskh", img, cp["attn"]["wk"])
            xv = jnp.einsum("bsd,dkh->bskh", img, cp["attn"]["wv"])
            a = L.gqa_attention(cp["attn"], L.rmsnorm(h, cp["ln1"]), cfg,
                                causal=False, kv_override=(xk, xv),
                                kv_chunk=kv_chunk)
            h = h + jnp.tanh(cp["gate"]).astype(h.dtype) * a
            h = h + L.swiglu(cp["mlp"], L.rmsnorm(h, cp["ln2"]))
            return h, (ks, vs, model.constrain_kv(xk),
                       model.constrain_kv(xv))

        x, (ks, vs, xks, xvs) = _scan_u(
            group, x, (params["layers"], params["cross_layers"]))
        cache = {"k": ks, "v": vs, "xk": xks, "xv": xvs, "len": jnp.int32(s)}

    else:
        raise ValueError(cfg.family)

    logits = L.unembed(params["embed"], x)
    return logits, cache
