"""Mamba2 / SSD block (state-space duality, arXiv:2405.21060).

Used by ``mamba2-780m`` (pure SSM) and ``zamba2-2.7b`` (hybrid backbone).

Training/prefill uses the chunked SSD algorithm: the sequence is cut into
chunks of Q tokens; within a chunk the contribution is a masked quadratic
(attention-like) einsum, across chunks a single recurrent state
``h ∈ [B, H, hd, N]`` is carried by ``lax.scan``.  Cost is
O(S·Q·(hd+N)·H) — linear in S — and the per-chunk tensors are the only
transients, so 32k prefill and 500k decode both fit.

Decode is the O(1) recurrence: ``h ← h·exp(dtA) + dt·x ⊗ B; y = C·h``.

Simplifications vs the reference CUDA implementation (recorded in
DESIGN.md): n_groups = 1 (the Mamba2 default), causal-conv width 4 on the
(x, B, C) channels, gated RMSNorm before out-projection.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rmsnorm
from repro.models.params import ParamInfo
from repro.utils.config import ModelConfig

CONV_W = 4


def ssm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_in = cfg.ssm_expand * cfg.d_model
    heads = d_in // cfg.ssm_head_dim
    return d_in, heads, cfg.ssm_state


def ssm_infos(cfg: ModelConfig) -> Dict[str, ParamInfo]:
    d = cfg.d_model
    d_in, h, n = ssm_dims(cfg)
    conv_ch = d_in + 2 * n                       # x, B, C channels (G=1)
    return {
        "w_xz": ParamInfo((d, 2 * d_in), ("embed", "ff")),
        "w_bc": ParamInfo((d, 2 * n), ("embed", None)),
        "w_dt": ParamInfo((d, h), ("embed", None)),
        "dt_bias": ParamInfo((h,), (None,), init="zeros", dtype=jnp.float32),
        "a_log": ParamInfo((h,), (None,), init="zeros", dtype=jnp.float32),
        "d_skip": ParamInfo((h,), (None,), init="ones", dtype=jnp.float32),
        "conv_w": ParamInfo((CONV_W, conv_ch), ("conv", "ff"), scale=0.5),
        "norm": ParamInfo((d_in,), ("ff",), init="ones"),
        "out_proj": ParamInfo((d_in, d), ("ff", "embed")),
    }


class SSMState(NamedTuple):
    """Decode-time state: recurrent h + causal-conv tail."""

    h: jnp.ndarray          # [B, H, hd, N] float32
    conv: jnp.ndarray       # [B, CONV_W - 1, conv_ch]


def init_ssm_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> SSMState:
    d_in, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    return SSMState(
        h=jnp.zeros((batch, h, hd, n), jnp.float32),
        conv=jnp.zeros((batch, CONV_W - 1, d_in + 2 * n), dtype),
    )


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width CONV_W.  x: [B, S, C]; w: [CONV_W, C]."""
    pads = jnp.pad(x, ((0, 0), (CONV_W - 1, 0), (0, 0)))
    out = sum(pads[:, i:i + x.shape[1], :] * w[i] for i in range(CONV_W))
    return jax.nn.silu(out)


def _project(p, x: jnp.ndarray, cfg: ModelConfig):
    d_in, h, n = ssm_dims(cfg)
    xz = jnp.einsum("bsd,de->bse", x, p["w_xz"])
    x_in, z = jnp.split(xz, 2, axis=-1)
    bc = jnp.einsum("bsd,de->bse", x, p["w_bc"])
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"]).astype(jnp.float32)
        + p["dt_bias"])
    return x_in, z, bc, dt


def ssd_forward(p, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """Chunked SSD over the full sequence.  x: [B, S, D] → [B, S, D]."""
    y, _ = ssd_forward_with_state(p, x, cfg)
    return y


def ssd_forward_with_state(p, x: jnp.ndarray, cfg: ModelConfig
                           ) -> Tuple[jnp.ndarray, SSMState]:
    """Chunked SSD returning (output, final decode state) — exact prefill."""
    b, s, d = x.shape
    d_in, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0, f"seq {s} not divisible by ssm_chunk {q}"
    nc = s // q

    x_in, z, bc, dt = _project(p, x, cfg)
    conv_in = jnp.concatenate([x_in, bc], axis=-1)
    conv_out = _causal_conv(conv_in, p["conv_w"])
    x_c = conv_out[..., :d_in].reshape(b, s, h, hd)
    b_c = conv_out[..., d_in:d_in + n]                    # [B, S, N]
    c_c = conv_out[..., d_in + n:]                        # [B, S, N]

    a = -jnp.exp(p["a_log"])                              # [H], negative
    da = dt * a                                           # [B, S, H]

    # chunk views
    xq = x_c.reshape(b, nc, q, h, hd).astype(jnp.float32)
    bq = b_c.reshape(b, nc, q, n).astype(jnp.float32)
    cq = c_c.reshape(b, nc, q, n).astype(jnp.float32)
    dtq = dt.reshape(b, nc, q, h)
    daq = da.reshape(b, nc, q, h)

    def chunk_body(hstate, inp):
        xb, bb, cb, dtb, dab = inp                        # [B, Q, ...]
        cum = jnp.cumsum(dab, axis=1)                     # [B, Q, H]
        # intra-chunk: decay(i, j) = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]    # [B, Q, Q, H]
        mask = jnp.tril(jnp.ones((q, q), bool))
        decay = jnp.where(mask[None, :, :, None], jnp.exp(diff), 0.0)
        scores = jnp.einsum("bin,bjn->bij", cb, bb)       # [B, Q, Q]
        w = scores[..., None] * decay * dtb[:, None, :, :]
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xb)    # [B, Q, H, hd]

        # inter-chunk: contribution of the carried state
        state_decay = jnp.exp(cum)                        # [B, Q, H]
        y_inter = jnp.einsum("bin,bhpn,bih->bihp", cb, hstate, state_decay)

        # state update: h' = h * exp(total) + sum_j exp(total - cum_j) dt_j x_j B_j
        total = cum[:, -1, :]                             # [B, H]
        suffix = jnp.exp(total[:, None, :] - cum)         # [B, Q, H]
        upd = jnp.einsum("bjhp,bjn,bjh,bjh->bhpn", xb, bb, dtb, suffix)
        h_new = hstate * jnp.exp(total)[:, :, None, None] + upd
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, h, hd, n), jnp.float32)
    inputs = tuple(jnp.moveaxis(t, 1, 0) for t in (xq, bq, cq, dtq, daq))
    # checkpoint the chunk body: the [Q, Q, H] intra-chunk score tensors are
    # recomputed in backward instead of being saved across all chunks.
    from repro.models.layers import INNER_SCAN_UNROLL
    h_final, ys = jax.lax.scan(jax.checkpoint(chunk_body), h0, inputs,
                               unroll=INNER_SCAN_UNROLL or 1)  # [nc,B,Q,H,hd]
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, h, hd)
    y = y + p["d_skip"][None, None, :, None] * x_c.astype(jnp.float32)
    y = y.reshape(b, s, d_in).astype(x.dtype)

    # gated norm + out projection (mamba2 layout)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])

    # decode state: carried SSD state + causal-conv input tail
    conv_tail = conv_in[:, s - (CONV_W - 1):, :]
    return out, SSMState(h=h_final, conv=conv_tail)


def ssd_decode(p, x: jnp.ndarray, state: SSMState, cfg: ModelConfig
               ) -> Tuple[jnp.ndarray, SSMState]:
    """One-token recurrent step.  x: [B, 1, D] → ([B, 1, D], state)."""
    b = x.shape[0]
    d_in, h, n = ssm_dims(cfg)
    hd = cfg.ssm_head_dim

    x_in, z, bc, dt = _project(p, x, cfg)                 # S = 1
    conv_in = jnp.concatenate([x_in, bc], axis=-1)        # [B, 1, C]
    window = jnp.concatenate([state.conv, conv_in], axis=1)  # [B, CONV_W, C]
    conv_out = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]))
    new_conv = window[:, 1:, :]

    x_c = conv_out[:, :d_in].reshape(b, h, hd).astype(jnp.float32)
    b_c = conv_out[:, d_in:d_in + n].astype(jnp.float32)
    c_c = conv_out[:, d_in + n:].astype(jnp.float32)
    dt1 = dt[:, 0, :]                                     # [B, H]
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt1 * a)                              # [B, H]

    h_new = state.h * decay[:, :, None, None] + jnp.einsum(
        "bhp,bn,bh->bhpn", x_c, b_c, dt1)
    y = jnp.einsum("bn,bhpn->bhp", c_c, h_new)
    y = y + p["d_skip"][None, :, None] * x_c
    y = y.reshape(b, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"])
    out = jnp.einsum("bse,ed->bsd", y, p["out_proj"])
    return out, SSMState(h=h_new, conv=new_conv)
