from repro.distributed.sharding import cache_pspecs, cache_shardings, batch_axes
from repro.distributed.store import (store_pspecs, pad_store, shard_store,
                                     concat_stores, stack_stores)
from repro.distributed.compression import (ef_allreduce_tree, init_error_tree,
                                           quantize_int8, dequantize_int8,
                                           compression_ratio)
