"""PartitionStore layout over the data axis of a device mesh.

The CLIMBER store is the TPU analogue of the paper's HDFS blocks: a dense
``[P, cap, n]`` array plus per-record masks.  For distributed query execution
(`repro.core.refine.refine_sharded`) every store field must be sharded over
its leading partition axis so each device scans only its local shard.  These
helpers make that layout a one-liner:

  * :func:`store_pspecs`  — the PartitionSpec tree (every field: ``P(data)``);
  * :func:`pad_store`     — pad P up to a multiple of the axis size (ragged
    partition counts would otherwise be silently truncated by the per-device
    split); padding slots carry ``rec_gid = -1`` so they can never match;
  * :func:`shard_store`   — pad + ``device_put`` with NamedShardings.

Global partition ids are preserved: padding appends empty partitions at the
end, and planners only ever emit real partition ids, so a padded store is
query-for-query equivalent to the unpadded one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core.index import PartitionStore


def store_pspecs(data_axis: str = "data") -> PartitionStore:
    """PartitionSpec per store field: everything shards its leading P axis."""
    return PartitionStore(
        data=PS(data_axis), norms=PS(data_axis), rec_dfs=PS(data_axis),
        rec_gid=PS(data_axis), count=PS(data_axis))


def pad_store(store: PartitionStore, multiple: int) -> PartitionStore:
    """Append empty partitions so ``P % multiple == 0`` (no-op when it is).

    Padded slots are inert: ``rec_gid``/``rec_dfs`` are −1 (never a live
    record, never inside a node interval) and no planner emits their ids.
    """
    pad = (-store.num_partitions) % multiple
    if pad == 0:
        return store
    tail = lambda x: ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return PartitionStore(
        data=jnp.pad(store.data, tail(store.data)),
        norms=jnp.pad(store.norms, tail(store.norms)),
        rec_dfs=jnp.pad(store.rec_dfs, tail(store.rec_dfs),
                        constant_values=-1),
        rec_gid=jnp.pad(store.rec_gid, tail(store.rec_gid),
                        constant_values=-1),
        count=jnp.pad(store.count, tail(store.count)))


def concat_stores(stores, gid_maps=None) -> PartitionStore:
    """Fuse several shard stores into one union store along the P axis.

    The fleet's lossless full-scan fallback executes one ``dispatch_refine``
    over this union instead of a per-shard scatter/gather.  Slot capacities
    are padded to the fleet-wide max with inert slots (``rec_gid = -1``), so
    a fused scan touches exactly the union of live records.

    Args:
      stores: sequence of PartitionStore (same series_len).
      gid_maps: optional per-store ``[n_i]`` arrays mapping the store's local
        record ids to global ids; identity (with no offset) when omitted —
        pass maps whenever the shards' local id spaces overlap.
    """
    stores = list(stores)
    if not stores:
        raise ValueError("concat_stores needs at least one store")
    cap = max(s.capacity for s in stores)
    fields = {"data": [], "norms": [], "rec_dfs": [], "rec_gid": [],
              "count": []}
    for i, s in enumerate(stores):
        pad = cap - s.capacity
        slot = lambda x, cv=0: jnp.pad(
            x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
            constant_values=cv)
        gid = slot(s.rec_gid, -1)
        if gid_maps is not None:
            gmap = jnp.asarray(np.asarray(gid_maps[i], dtype=np.int32))
            gid = jnp.where(gid >= 0, gmap[jnp.maximum(gid, 0)], -1)
        fields["data"].append(slot(s.data))
        fields["norms"].append(slot(s.norms))
        fields["rec_dfs"].append(slot(s.rec_dfs, -1))
        fields["rec_gid"].append(gid)
        fields["count"].append(s.count)
    return PartitionStore(**{k: jnp.concatenate(v, axis=0)
                             for k, v in fields.items()})


def shard_store(store: PartitionStore, mesh, *,
                data_axis: str = "data") -> PartitionStore:
    """Lay the store out over ``data_axis``: pad P, then place each field."""
    store = pad_store(store, mesh.shape[data_axis])
    specs = store_pspecs(data_axis)
    return PartitionStore(*[
        jax.device_put(x, NamedSharding(mesh, s))
        for x, s in zip(store, specs)])
