"""PartitionStore layout over the data axis of a device mesh.

The CLIMBER store is the TPU analogue of the paper's HDFS blocks: a dense
``[P, cap, n]`` array plus per-record masks.  For distributed query execution
(`repro.core.refine.refine_sharded`) every store field must be sharded over
its leading partition axis so each device scans only its local shard.  These
helpers make that layout a one-liner:

  * :func:`store_pspecs`  — the PartitionSpec tree (every field: ``P(data)``);
  * :func:`pad_store`     — pad the leading axis up to a multiple of the
    axis size (a ragged count would otherwise be silently truncated by the
    per-device split); padding slots carry ``rec_gid = -1`` so they can
    never match;
  * :func:`shard_store`   — pad + ``device_put`` with NamedShardings;
  * :func:`store_to_arrays` / :func:`store_from_arrays` — the bit-exact
    host-array wire format the fleet's shard snapshots
    (``repro.fleet.lifecycle.snapshot``) serialize through.

Global partition ids are preserved: padding appends empty partitions at the
end, and planners only ever emit real partition ids, so a padded store is
query-for-query equivalent to the unpadded one.

The same helpers serve two layouts:

  * **partition-sharded** (single index): each field's leading axis is P,
    so every device scans a slice of one index's partitions
    (``refine_sharded``);
  * **shard-stacked** (fleet): :func:`stack_stores` stacks whole shard
    stores on a NEW leading shard axis ``S`` (ragged P/cap padded with
    inert slots, local gids remapped to fleet-global), after which
    ``pad_store``/``store_pspecs``/``shard_store`` apply verbatim to the
    shard axis — each device then owns whole indexes, which is how the
    fleet's mesh placement (``repro.fleet.placement``) lays a fleet out.
    The trie skeletons ride the same layout through the sibling helper
    :func:`repro.fleet.device_plan.stack_tries` (``[S, ...]`` padded trie
    tables next to the ``[S, ...]`` stacked stores), which is what lets the
    placement plan on device instead of looping shards on the host.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.core.index import PartitionStore


def store_pspecs(data_axis: str = "data") -> PartitionStore:
    """PartitionSpec per store field: everything shards its leading P axis."""
    return PartitionStore(
        data=PS(data_axis), norms=PS(data_axis), rec_dfs=PS(data_axis),
        rec_gid=PS(data_axis), count=PS(data_axis))


def pad_store(store: PartitionStore, multiple: int) -> PartitionStore:
    """Append empty partitions so ``P % multiple == 0`` (no-op when it is).

    Padded slots are inert: ``rec_gid``/``rec_dfs`` are −1 (never a live
    record, never inside a node interval) and no planner emits their ids.
    """
    pad = (-store.num_partitions) % multiple
    if pad == 0:
        return store
    tail = lambda x: ((0, pad),) + ((0, 0),) * (x.ndim - 1)
    return PartitionStore(
        data=jnp.pad(store.data, tail(store.data)),
        norms=jnp.pad(store.norms, tail(store.norms)),
        rec_dfs=jnp.pad(store.rec_dfs, tail(store.rec_dfs),
                        constant_values=-1),
        rec_gid=jnp.pad(store.rec_gid, tail(store.rec_gid),
                        constant_values=-1),
        count=jnp.pad(store.count, tail(store.count)))


def _pad_caps(store: PartitionStore, cap: int,
              gid_map=None) -> PartitionStore:
    """Pad slot capacity to ``cap`` with inert slots; optionally remap the
    store's local record ids to global ids (``gid_map[n_local] -> gid``)."""
    pad = cap - store.capacity
    slot = lambda x, cv=0: jnp.pad(
        x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2),
        constant_values=cv)
    gid = slot(store.rec_gid, -1)
    if gid_map is not None:
        gmap = jnp.asarray(np.asarray(gid_map, dtype=np.int32))
        gid = jnp.where(gid >= 0, gmap[jnp.maximum(gid, 0)], -1)
    return PartitionStore(
        data=slot(store.data), norms=slot(store.norms),
        rec_dfs=slot(store.rec_dfs, -1), rec_gid=gid, count=store.count)


def stack_stores(stores, gid_maps=None) -> PartitionStore:
    """Stack shard stores on a NEW leading shard axis (``S`` first).

    Every field becomes ``[S, ...]`` — ``data [S, P, cap, n]``, ``count
    [S, P]`` — with ragged partition counts and slot capacities padded to
    the fleet-wide maxima using inert slots (``rec_gid = rec_dfs = -1``,
    never inside a node interval, never a live record).  This is the
    layout the fleet's mesh placement shards over the data axis: device d
    holds whole shards ``[d·per, (d+1)·per)``, and ``pad_store`` /
    ``store_pspecs`` apply to the shard axis unchanged.

    Args:
      stores: sequence of PartitionStore (same series_len).
      gid_maps: optional per-store ``[n_i]`` arrays mapping each store's
        local record ids to fleet-global ids; identity when omitted.
    """
    stores = list(stores)
    if not stores:
        raise ValueError("stack_stores needs at least one store")
    cap = max(s.capacity for s in stores)
    pmax = max(s.num_partitions for s in stores)
    padded = []
    for i, s in enumerate(stores):
        s = _pad_caps(s, cap, None if gid_maps is None else gid_maps[i])
        padded.append(pad_store(s, pmax) if s.num_partitions < pmax else s)
    return PartitionStore(*[jnp.stack(x) for x in zip(*padded)])


def concat_stores(stores, gid_maps=None) -> PartitionStore:
    """Fuse several shard stores into one union store along the P axis.

    The fleet's lossless full-scan fallback executes one ``dispatch_refine``
    over this union instead of a per-shard scatter/gather.  Slot capacities
    are padded to the fleet-wide max with inert slots (``rec_gid = -1``), so
    a fused scan touches exactly the union of live records.

    Args:
      stores: sequence of PartitionStore (same series_len).
      gid_maps: optional per-store ``[n_i]`` arrays mapping the store's local
        record ids to global ids; identity (with no offset) when omitted —
        pass maps whenever the shards' local id spaces overlap.
    """
    stores = list(stores)
    if not stores:
        raise ValueError("concat_stores needs at least one store")
    cap = max(s.capacity for s in stores)
    padded = [_pad_caps(s, cap, None if gid_maps is None else gid_maps[i])
              for i, s in enumerate(stores)]
    return PartitionStore(*[jnp.concatenate(x, axis=0)
                            for x in zip(*padded)])


def shard_store(store: PartitionStore, mesh, *,
                data_axis: str = "data") -> PartitionStore:
    """Lay the store out over ``data_axis``: pad P, then place each field."""
    store = pad_store(store, mesh.shape[data_axis])
    specs = store_pspecs(data_axis)
    return PartitionStore(*[
        jax.device_put(x, NamedSharding(mesh, s))
        for x, s in zip(store, specs)])


def store_to_arrays(store: PartitionStore, prefix: str = "store_"):
    """Host-array dict of every store field (the snapshot wire format).

    Keys are ``f"{prefix}{field}"`` so several stores (or a store plus
    other arrays) can share one ``npz``.  Inverse of
    :func:`store_from_arrays`; the round trip is bit-exact, which is what
    makes a restored shard's answers bit-identical
    (``repro.fleet.lifecycle.snapshot``).
    """
    return {prefix + name: np.asarray(getattr(store, name))
            for name in PartitionStore._fields}


def store_from_arrays(arrays, prefix: str = "store_") -> PartitionStore:
    """Rebuild a device-resident store from :func:`store_to_arrays` output."""
    return PartitionStore(*[jnp.asarray(arrays[prefix + name])
                            for name in PartitionStore._fields])
