"""Activation/cache sharding rules (the in/out sharding contract).

Weights follow ``repro.models.params.param_pspecs``.  Caches follow the
per-family rules below:

  * KV caches shard the **kv-heads dim over `model`** when divisible —
    zero-collective decode attention;
  * otherwise they shard the **sequence dim over `model`** (flash-decoding
    style: GSPMD turns the softmax over the sharded seq into partial-softmax
    + all-reduce) — this covers kv=4/8/20/40 archs on the 16-way axis;
  * SSM states shard heads over `model` (mamba heads are plentiful), conv
    tails shard channels;
  * batch always shards over every non-model axis (pod × data).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
from jax.sharding import NamedSharding, PartitionSpec as PS

from repro.models import ssm as SSM_mod
from repro.utils.config import ModelConfig


def _axes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def batch_axes(mesh):
    return tuple(a for a in mesh.axis_names if a != "model")


def _div(n: int, mesh, axis="model") -> bool:
    return n % _axes(mesh)[axis] == 0


def cache_pspecs(cfg: ModelConfig, mesh, batch: int, max_len: int,
                 enc_len: int = 0, img_len: int = 0) -> Dict[str, Any]:
    """PartitionSpec tree matching ``repro.models.decoding.cache_shapes``."""
    import numpy as np
    ba = batch_axes(mesh)
    sizes = _axes(mesh)
    n_batch = int(np.prod([sizes[a] for a in ba]))
    if batch % n_batch != 0:
        ba = None                     # e.g. global_batch=1 long-context decode
    kv_ok = _div(cfg.num_kv_heads, mesh) and not cfg.use_mla
    seq_ok = _div(max_len, mesh)

    def kv_spec(lead: int, seq_dim_len: int):
        """[*lead, B, S, KV, hd] — prefer heads sharding, else seq."""
        lead_spec = (None,) * lead
        if kv_ok:
            return PS(*lead_spec, ba, None, "model", None)
        if seq_dim_len % _axes(mesh)["model"] == 0:
            return PS(*lead_spec, ba, "model", None, None)
        return PS(*lead_spec, ba, None, None, None)

    if cfg.family in ("dense", "moe") and not cfg.use_mla:
        return {"k": kv_spec(1, max_len), "v": kv_spec(1, max_len), "len": PS()}
    if cfg.use_mla:
        s = PS(None, ba, "model", None) if seq_ok else PS(None, ba, None, None)
        return {"ckv": s, "len": PS()}
    if cfg.family == "ssm":
        _, h, _ = SSM_mod.ssm_dims(cfg)
        hspec = "model" if _div(h, mesh) else None
        d_in, _, n = SSM_mod.ssm_dims(cfg)
        conv_ch = d_in + 2 * n
        cspec = "model" if _div(conv_ch, mesh) else None
        return {"h": PS(None, ba, hspec, None, None),
                "conv": PS(None, ba, None, cspec), "len": PS()}
    if cfg.family == "hybrid":
        d_in, h, n = SSM_mod.ssm_dims(cfg)
        conv_ch = d_in + 2 * n
        hspec = "model" if _div(h, mesh) else None
        cspec = "model" if _div(conv_ch, mesh) else None
        return {"h": PS(None, None, ba, hspec, None, None),
                "conv": PS(None, None, ba, None, cspec),
                "k": kv_spec(1, max_len), "v": kv_spec(1, max_len),
                "len": PS()}
    if cfg.family == "encdec":
        return {"k": kv_spec(1, max_len), "v": kv_spec(1, max_len),
                "xk": kv_spec(1, enc_len), "xv": kv_spec(1, enc_len),
                "len": PS()}
    if cfg.family == "vlm":
        return {"k": kv_spec(2, max_len), "v": kv_spec(2, max_len),
                "xk": kv_spec(1, img_len), "xv": kv_spec(1, img_len),
                "len": PS()}
    raise ValueError(cfg.family)


def cache_shardings(cfg: ModelConfig, mesh, batch: int, max_len: int,
                    enc_len: int = 0, img_len: int = 0):
    specs = cache_pspecs(cfg, mesh, batch, max_len, enc_len, img_len)
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, PS))
