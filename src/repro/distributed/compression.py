"""Gradient compression: int8 error-feedback all-reduce.

Cross-pod DCI links are the slowest hop in a multi-pod job, and the only
traffic they must carry is the once-per-step gradient all-reduce.  This
module quantises gradients to int8 with a per-tensor scale before the
cross-pod psum and keeps the quantisation error as local feedback state
(added back before the next step's quantisation) — the classic EF-SGD
scheme, which preserves convergence where plain one-shot quantisation
doesn't.

Usage: wrap per-shard gradients inside a shard_map (the pod axis must be a
manual axis), carrying ``error`` state alongside the optimizer state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8 quantisation.  Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def ef_allreduce_leaf(grad: jnp.ndarray, error: jnp.ndarray,
                      axis_name: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Error-feedback compressed mean over ``axis_name`` for one tensor.

    Returns (reduced_grad_f32, new_error).
    """
    g32 = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(g32)
    deq = dequantize_int8(q, scale)
    new_error = g32 - deq                      # local feedback memory
    # psum of the dequantised payload models int8 wire traffic + fp32 combine
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    reduced = jax.lax.psum(deq, axis_name) / n
    return reduced, new_error


def ef_allreduce_tree(grads: Any, errors: Any, axis_name: str
                      ) -> Tuple[Any, Any]:
    """Tree version: apply ef_allreduce_leaf leaf-wise."""
    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    out_g, out_e = [], []
    for g, e in zip(flat_g, flat_e):
        rg, ne = ef_allreduce_leaf(g, e, axis_name)
        out_g.append(rg)
        out_e.append(ne)
    return (jax.tree_util.tree_unflatten(tdef, out_g),
            jax.tree_util.tree_unflatten(tdef, out_e))


def init_error_tree(params: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(tree: Any) -> float:
    """Wire-bytes ratio of int8+scale vs fp32 for a gradient tree."""
    total_f32 = sum(x.size * 4 for x in jax.tree_util.tree_leaves(tree))
    total_q = sum(x.size * 1 + 4 for x in jax.tree_util.tree_leaves(tree))
    return total_q / total_f32
