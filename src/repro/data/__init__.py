from repro.data.series import (GENERATORS, make_dataset, make_queries,
                               random_walk, sift_like, dna_like, eeg_like,
                               seismic_like)

__all__ = ["GENERATORS", "make_dataset", "make_queries", "random_walk",
           "sift_like", "dna_like", "eeg_like", "seismic_like"]
