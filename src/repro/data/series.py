"""Synthetic data-series generators matching the paper's datasets (§VII-A).

  * RandomWalk — the standard data-series index benchmark [12,21,39,54]:
    cumulative sums of N(0,1) steps, z-normalised.
  * SIFT-like  — Texmex-style clustered feature vectors (mixture of Gaussians
    around random centers; image descriptors cluster heavily).
  * DNA-like   — smoothed step series from a 4-letter alphabet random walk,
    mimicking the UCSC assembly conversion of [12].
  * EEG-like   — sums of band-limited sinusoids + noise (seizure EEG records
    are oscillatory).
  * Seismic-like — AR(1)-correlated noise with sparse decaying-oscillation
    bursts (the Hydra benchmarks' seismic records: long coloured-noise
    stretches punctuated by event arrivals).

All generators are deterministic in the PRNG key, jit-able, and emit float32
``[N, n]``.  Queries are drawn from the dataset itself, as in the paper
("query objects are randomly selected from the entire dataset").
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.paa import znormalize


def random_walk(key: jax.Array, num: int, length: int) -> jnp.ndarray:
    steps = jax.random.normal(key, (num, length), dtype=jnp.float32)
    return znormalize(jnp.cumsum(steps, axis=-1))


def sift_like(key: jax.Array, num: int, length: int,
              num_clusters: int = 64, spread: float = 0.15) -> jnp.ndarray:
    kc, ka, kn = jax.random.split(key, 3)
    centers = jax.random.normal(kc, (num_clusters, length), dtype=jnp.float32)
    assign = jax.random.randint(ka, (num,), 0, num_clusters)
    noise = jax.random.normal(kn, (num, length), dtype=jnp.float32) * spread
    return znormalize(centers[assign] + noise)


def dna_like(key: jax.Array, num: int, length: int,
             smooth: int = 8) -> jnp.ndarray:
    k1, = jax.random.split(key, 1)
    # 4-letter alphabet mapped to levels, random-walk accumulated as in [12]
    letters = jax.random.randint(k1, (num, length), 0, 4).astype(jnp.float32)
    levels = letters - 1.5
    walk = jnp.cumsum(levels, axis=-1)
    kernel = jnp.ones((smooth,), dtype=jnp.float32) / smooth
    smoothed = jax.vmap(lambda s: jnp.convolve(s, kernel, mode="same"))(walk)
    return znormalize(smoothed)


def eeg_like(key: jax.Array, num: int, length: int,
             num_bands: int = 5) -> jnp.ndarray:
    kf, kp, ka, kn = jax.random.split(key, 4)
    freqs = jax.random.uniform(kf, (num, num_bands), minval=0.5, maxval=40.0)
    phases = jax.random.uniform(kp, (num, num_bands), maxval=2 * jnp.pi)
    amps = jax.random.uniform(ka, (num, num_bands), minval=0.2, maxval=1.0)
    t = jnp.arange(length, dtype=jnp.float32) / 400.0   # 400 Hz sampling
    waves = amps[..., None] * jnp.sin(
        2 * jnp.pi * freqs[..., None] * t + phases[..., None])
    noise = jax.random.normal(kn, (num, length)) * 0.3
    return znormalize(jnp.sum(waves, axis=1) + noise)


def seismic_like(key: jax.Array, num: int, length: int,
                 corr: float = 0.97, num_events: int = 3) -> jnp.ndarray:
    kn, kt, kf, ka = jax.random.split(key, 4)
    # coloured background: white noise convolved with an AR(1) impulse
    # response (geometric tail), the classic microseism spectrum shape
    white = jax.random.normal(kn, (num, length), dtype=jnp.float32)
    tail = corr ** jnp.arange(32, dtype=jnp.float32)
    background = jax.vmap(
        lambda s: jnp.convolve(s, tail, mode="same"))(white)
    # sparse event arrivals: exponentially decaying sinusoid bursts at
    # random onsets/frequencies (P/S-wave codas)
    t = jnp.arange(length, dtype=jnp.float32)
    onset = jax.random.uniform(kt, (num, num_events),
                               maxval=0.8 * length)
    freq = jax.random.uniform(kf, (num, num_events), minval=0.05,
                              maxval=0.3)
    amp = jax.random.uniform(ka, (num, num_events), minval=2.0, maxval=6.0)
    dt = t[None, None, :] - onset[..., None]                # [N, E, n]
    coda = jnp.where(dt >= 0,
                     jnp.exp(-dt / 12.0) * jnp.sin(2 * jnp.pi
                                                   * freq[..., None] * dt),
                     0.0)
    events = jnp.sum(amp[..., None] * coda, axis=1)
    return znormalize(background + events)


GENERATORS = {
    "randomwalk": random_walk,
    "sift": sift_like,
    "dna": dna_like,
    "eeg": eeg_like,
    "seismic": seismic_like,
}


def make_dataset(name: str, key: jax.Array, num: int, length: int) -> jnp.ndarray:
    return GENERATORS[name](key, num, length)


def make_queries(key: jax.Array, data: jnp.ndarray, num_queries: int) -> jnp.ndarray:
    """Paper §VII-A: queries are random members of the dataset."""
    idx = jax.random.choice(key, data.shape[0], shape=(num_queries,),
                            replace=False)
    return data[idx]
