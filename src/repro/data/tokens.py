"""Synthetic token pipeline: deterministic, shardable, resumable.

Real fleets stream tokenised shards from object storage; what matters for
the framework is the *contract*, which this pipeline honours exactly:
  * deterministic in (seed, step) — a restore replays the same batches;
  * host-local sharding — each process materialises only its slice of the
    global batch (``process_slice``);
  * constant-time seek — resuming at step N costs O(1), not O(N);
  * family-aware — emits frames/image stubs for encdec/vlm archs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.utils.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    cfg: ModelConfig
    global_batch: int
    seq_len: int
    seed: int = 0
    mode: str = "uniform"       # "uniform" (entropy floor) | "periodic"
                                # (learnable structure — demos/examples)

    def batch_at(self, step: int, *, lo: int = 0, hi: Optional[int] = None
                 ) -> Dict[str, jnp.ndarray]:
        """The (sub-)batch for one step; [lo, hi) selects the host's rows."""
        hi = self.global_batch if hi is None else hi
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        kt, kf, ki = jax.random.split(key, 3)
        n = hi - lo
        # fold in the row range so any host slice is reproducible standalone
        kt = jax.random.fold_in(kt, lo)
        if self.mode == "periodic":
            # next-token-predictable modular walk with random per-row phase
            phase = jax.random.randint(kt, (n, 1), 0, self.cfg.vocab_size)
            t = jnp.arange(self.seq_len + 1)[None, :]
            stride = 1 + (step % 3)
            tokens = (phase + stride * t) % self.cfg.vocab_size
            batch = {"tokens": tokens.astype(jnp.int32)}
        else:
            batch = {"tokens": jax.random.randint(
                kt, (n, self.seq_len + 1), 0, self.cfg.vocab_size, jnp.int32)}
        if self.cfg.family == "encdec":
            batch["frames"] = jax.random.normal(
                kf, (n, self.seq_len, self.cfg.d_model),
                jnp.float32).astype(jnp.bfloat16)
        if self.cfg.family == "vlm":
            batch["image_embeds"] = jax.random.normal(
                ki, (n, self.cfg.num_image_tokens, self.cfg.d_model),
                jnp.float32).astype(jnp.bfloat16)
        return batch

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1

    def state_dict(self, step: int) -> Dict:
        return {"seed": self.seed, "step": step,
                "global_batch": self.global_batch, "seq_len": self.seq_len}
