# Pallas TPU kernels for the compute hot-spots of the CLIMBER pipeline.
#
#   l2.py          — tiled pairwise / per-query squared-ED matmuls
#   paa_kernel.py  — PAA mean-pool
#   pivot_rank.py  — fused pivot-distance + top-m prefix (P4→ signatures)
#   refine_topk.py — streaming fused refine: masked ED + online top-k per
#                    scalar-prefetched plan entry (never materializes the
#                    [Q, slots, cap] distance tensor)
#
# ops.py holds the jit'd public wrappers (interpret mode on CPU, compiled
# on TPU); ref.py the pure-jnp oracles every kernel is validated against
# (tests/test_kernels.py, tests/test_refine_topk.py).
