"""Pallas TPU kernel: fused pivot-distance + top-m prefix extraction.

P4→ signature generation (paper Def. 5/6) is the hot op of both index
construction (step 4 touches every record) and query featurisation:
distances to all r pivots followed by the m smallest.  Fusing the two keeps
the [BLOCK_B, r] distance tile in VMEM and never materialises it in HBM —
for r=200 that saves an 800-byte round trip per record, turning a
bandwidth-bound argsort pipeline into a compute-bound matmul + m-step
min-extraction (m ≤ ~20, unrolled; each step is a masked row-min on the VPU).

Tie-breaking matches the oracle (``jax.lax.top_k`` on negated distances):
equal distances resolve toward the lower pivot id.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256
_INF = 3.4e38  # python float: jnp scalars would be captured as consts


def _pivot_rank_kernel(paa_ref, piv_ref, out_ref, *, m: int):
    x = paa_ref[...].astype(jnp.float32)          # [bb, w]
    p = piv_ref[...].astype(jnp.float32)          # [r, w]
    r = p.shape[0]
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    p2 = jnp.sum(p * p, axis=-1)[None, :]
    ab = jax.lax.dot_general(x, p, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    d = jnp.maximum(x2 - 2.0 * ab + p2, 0.0)      # [bb, r]

    ids = jax.lax.broadcasted_iota(jnp.int32, d.shape, 1)
    for i in range(m):                            # static unroll, m small
        # row-min with lower-id tie-break: argmin scans ascending ids
        winner = jnp.argmin(d, axis=-1).astype(jnp.int32)   # [bb]
        out_ref[:, i] = winner
        d = jnp.where(ids == winner[:, None], _INF, d)


@functools.partial(jax.jit, static_argnames=("m", "block_b", "interpret"))
def pivot_rank(paa: jnp.ndarray, pivots: jnp.ndarray, m: int, *,
               block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = False) -> jnp.ndarray:
    """Fused P4→ signature: ``[B, w]`` × ``[r, w]`` → ``[B, m]`` int32."""
    b, w = paa.shape
    r = pivots.shape[0]
    if m > r:
        raise ValueError(f"prefix m={m} exceeds r={r}")
    bb = min(block_b, max(b, 1))
    b_pad = (-b) % bb
    if b_pad:
        paa = jnp.pad(paa, ((0, b_pad), (0, 0)))
    gb = paa.shape[0] // bb

    out = pl.pallas_call(
        functools.partial(_pivot_rank_kernel, m=m),
        grid=(gb,),
        in_specs=[
            pl.BlockSpec((bb, w), lambda i: (i, 0)),
            pl.BlockSpec((r, w), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((paa.shape[0], m), jnp.int32),
        interpret=interpret,
    )(paa, pivots)
    return out[:b]
