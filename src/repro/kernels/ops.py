"""Jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels execute in ``interpret=True`` mode — the
kernel bodies run as Python/jnp on the host, which validates the exact TPU
code path.  On a real TPU backend ``interpret`` flips to False automatically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import l2 as _l2
from repro.kernels import paa_kernel as _paa_k
from repro.kernels import pivot_rank as _pr
from repro.kernels import refine_topk as _rt


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pairwise_l2(q: jnp.ndarray, x: jnp.ndarray, **kw) -> jnp.ndarray:
    """Squared ED matrix ``[Q, C]`` (see kernels/l2.py)."""
    return _l2.pairwise_l2(q, x, interpret=_interpret(), **kw)


def qdots(q: jnp.ndarray, rows: jnp.ndarray, **kw) -> jnp.ndarray:
    """Per-query candidate dots ``[Q, C]`` (see kernels/l2.py)."""
    return _l2.qdots(q, rows, interpret=_interpret(), **kw)


def batched_query_dots(q: jnp.ndarray, rows: jnp.ndarray, **kw) -> jnp.ndarray:
    """Per-entry candidate dots: rows ``[Q, MP, cap, n]`` → ``[Q, MP, cap]``.

    Formerly the refine-stage distance hot loop; superseded there by the
    streaming :func:`fused_refine_topk` (which never gathers ``rows``).
    Kept as a validated building block for gather-style consumers and the
    kernel parity suite/µbench.
    """
    qn, mp, cap, n = rows.shape
    flat = rows.reshape(qn, mp * cap, n)
    return qdots(q, flat, **kw).reshape(qn, mp, cap)


def fused_refine_topk(data, norms, rec_dfs, rec_gid, queries,
                      sel_part, sel_lo, sel_hi, k: int, **kw):
    """Streaming fused masked-ED + top-k (see kernels/refine_topk.py).

    The plan must be sorted by partition id along the entry axis.  Returns
    ``[Q, k]`` (squared distances, gids); never materializes the
    ``[Q, MP, cap]`` distance tensor or the gathered candidate rows.  The
    candidate-block width is picked at trace time from the store capacity
    (``pick_block_c``) unless ``block_c=`` pins it.
    """
    return _rt.refine_topk(data, norms, rec_dfs, rec_gid, queries,
                           sel_part, sel_lo, sel_hi, k,
                           interpret=_interpret(), **kw)


def fused_refine_topk_device_plan(data, norms, rec_dfs, rec_gid, queries,
                                  sel_part, sel_lo, sel_hi, k: int, **kw):
    """:func:`fused_refine_topk` over a plan that is already device-resident
    but not yet partition-sorted — e.g. straight out of a device planner in
    the same program (the fleet's fused mesh pass).

    The partition sort the scalar-prefetch grid requires happens here as a
    traced stable argsort (pads-first, ties by entry slot), so the plan
    never round-trips to the host between planning and refine.  With an
    already-sorted plan the sort is the identity permutation — calling this
    instead of :func:`fused_refine_topk` is always safe, just one argsort
    heavier.
    """
    order = jnp.argsort(sel_part, axis=-1, stable=True)
    take = lambda t: jnp.take_along_axis(t, order, axis=-1)
    return _rt.refine_topk(data, norms, rec_dfs, rec_gid, queries,
                           take(sel_part), take(sel_lo), take(sel_hi), k,
                           interpret=_interpret(), **kw)


def paa(x: jnp.ndarray, segments: int, **kw) -> jnp.ndarray:
    """PAA mean-pool ``[B, n]`` → ``[B, w]`` (see kernels/paa_kernel.py)."""
    return _paa_k.paa(x, segments, interpret=_interpret(), **kw)


def pivot_rank(paa_sig: jnp.ndarray, pivots: jnp.ndarray, m: int, **kw) -> jnp.ndarray:
    """Fused P4→ generation ``[B, m]`` (see kernels/pivot_rank.py)."""
    return _pr.pivot_rank(paa_sig, pivots, m, interpret=_interpret(), **kw)
