"""Pallas TPU kernel: tiled pairwise squared-L2 (the ED-refine hot spot).

The refine stage of CLIMBER-kNN compares a block of queries against the raw
series of the selected partitions (paper §VI, "Localized Record-Level
Similarity").  On TPU we tile the [Q, C] distance matrix into
(BLOCK_Q × BLOCK_C) VMEM blocks and compute ‖q‖² − 2·q·xᵀ + ‖x‖² with the
−2·q·xᵀ term on the MXU — arithmetic intensity ≈ n FLOPs/byte per tile, so
for n ≥ 128 the tile is compute-bound, exactly where the MXU wants to live.

Blocking: BLOCK_Q × n and BLOCK_C × n operand tiles plus the BLOCK_Q × BLOCK_C
output tile must fit VMEM (~16 MB on v5e).  With the defaults
(128 × 512 fp32 out + two 128/512 × n fp32 operands, n ≤ 1024) the working
set stays < 3 MB, leaving headroom for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_C = 512


def _l2_kernel(q_ref, x_ref, out_ref):
    """One (BLOCK_Q, BLOCK_C) tile of the squared-distance matrix."""
    q = q_ref[...].astype(jnp.float32)            # [bq, n]
    x = x_ref[...].astype(jnp.float32)            # [bc, n]
    q2 = jnp.sum(q * q, axis=-1, keepdims=True)   # [bq, 1]
    x2 = jnp.sum(x * x, axis=-1)[None, :]         # [1, bc]
    # MXU matmul; accumulate in fp32 regardless of input dtype.
    ab = jax.lax.dot_general(q, x, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    out_ref[...] = jnp.maximum(q2 - 2.0 * ab + x2, 0.0)


@functools.partial(jax.jit, static_argnames=("block_q", "block_c", "interpret"))
def pairwise_l2(q: jnp.ndarray, x: jnp.ndarray, *,
                block_q: int = DEFAULT_BLOCK_Q,
                block_c: int = DEFAULT_BLOCK_C,
                interpret: bool = False) -> jnp.ndarray:
    """Squared ED: q ``[Q, n]`` × x ``[C, n]`` → ``[Q, C]`` float32.

    Shapes are padded up to block multiples; the pad region is sliced off.
    """
    qn, n = q.shape
    cn = x.shape[0]
    bq = min(block_q, max(qn, 1))
    bc = min(block_c, max(cn, 1))
    q_pad = (-qn) % bq
    c_pad = (-cn) % bc
    if q_pad:
        q = jnp.pad(q, ((0, q_pad), (0, 0)))
    if c_pad:
        x = jnp.pad(x, ((0, c_pad), (0, 0)))
    gq, gc = q.shape[0] // bq, x.shape[0] // bc

    out = pl.pallas_call(
        _l2_kernel,
        grid=(gq, gc),
        in_specs=[
            pl.BlockSpec((bq, n), lambda i, j: (i, 0)),
            pl.BlockSpec((bc, n), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bq, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q.shape[0], x.shape[0]), jnp.float32),
        interpret=interpret,
    )(q, x)
    return out[:qn, :cn]


def _qdots_kernel(q_ref, rows_ref, out_ref):
    """Per-query dots: one query row against a block of its candidates."""
    q = q_ref[...].astype(jnp.float32)            # [1, n]
    rows = rows_ref[...].astype(jnp.float32)      # [1, bc, n]
    out_ref[...] = jax.lax.dot_general(
        q, rows[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)       # [1, bc]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def qdots(q: jnp.ndarray, rows: jnp.ndarray, *,
          block_c: int = DEFAULT_BLOCK_C,
          interpret: bool = False) -> jnp.ndarray:
    """Batched per-query dots: q ``[Q, n]``, rows ``[Q, C, n]`` → ``[Q, C]``.

    This is the masked-refine inner product where every query owns its own
    gathered candidate matrix (selected partitions differ per query).
    """
    qn, n = q.shape
    cn = rows.shape[1]
    bc = min(block_c, max(cn, 1))
    c_pad = (-cn) % bc
    if c_pad:
        rows = jnp.pad(rows, ((0, 0), (0, c_pad), (0, 0)))
    gc = rows.shape[1] // bc

    out = pl.pallas_call(
        _qdots_kernel,
        grid=(qn, gc),
        in_specs=[
            pl.BlockSpec((1, n), lambda i, j: (i, 0)),
            pl.BlockSpec((1, bc, n), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qn, rows.shape[1]), jnp.float32),
        interpret=interpret,
    )(q, rows)
    return out[:, :cn]
