"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (see
tests/test_kernels.py for the shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l2_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Squared ED between every query and every candidate.

    q: [Q, n], x: [C, n] → [Q, C] float32.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1)[:, None]
    x2 = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(q2 - 2.0 * (q @ x.T) + x2, 0.0)


def qdots_ref(q: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Per-query dot products against that query's own candidate rows.

    q: [Q, n], rows: [Q, C, n] → [Q, C] float32.
    """
    return jnp.einsum("qn,qcn->qc", q.astype(jnp.float32),
                      rows.astype(jnp.float32))


def paa_ref(x: jnp.ndarray, segments: int) -> jnp.ndarray:
    """PAA mean-pool.  x: [B, n] → [B, w] float32."""
    b, n = x.shape
    seg = n // segments
    return jnp.mean(x.astype(jnp.float32).reshape(b, segments, seg), axis=-1)


def pivot_rank_ref(paa: jnp.ndarray, pivots: jnp.ndarray, m: int) -> jnp.ndarray:
    """Fused pivot distance + top-m prefix extraction.

    paa: [B, w], pivots: [r, w] → [B, m] int32 (ids of m nearest pivots,
    ascending distance, ties toward the lower id).
    """
    paa = paa.astype(jnp.float32)
    pivots = pivots.astype(jnp.float32)
    a2 = jnp.sum(paa * paa, axis=-1, keepdims=True)
    b2 = jnp.sum(pivots * pivots, axis=-1)
    d = jnp.maximum(a2 - 2.0 * (paa @ pivots.T) + b2, 0.0)
    _, idx = jax.lax.top_k(-d, m)
    return idx.astype(jnp.int32)
