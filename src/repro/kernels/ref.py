"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; the kernels must match them (see
tests/test_kernels.py for the shape/dtype sweeps).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def pairwise_l2_ref(q: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """Squared ED between every query and every candidate.

    q: [Q, n], x: [C, n] → [Q, C] float32.
    """
    q = q.astype(jnp.float32)
    x = x.astype(jnp.float32)
    q2 = jnp.sum(q * q, axis=-1)[:, None]
    x2 = jnp.sum(x * x, axis=-1)[None, :]
    return jnp.maximum(q2 - 2.0 * (q @ x.T) + x2, 0.0)


def qdots_ref(q: jnp.ndarray, rows: jnp.ndarray) -> jnp.ndarray:
    """Per-query dot products against that query's own candidate rows.

    q: [Q, n], rows: [Q, C, n] → [Q, C] float32.
    """
    return jnp.einsum("qn,qcn->qc", q.astype(jnp.float32),
                      rows.astype(jnp.float32))


def paa_ref(x: jnp.ndarray, segments: int) -> jnp.ndarray:
    """PAA mean-pool.  x: [B, n] → [B, w] float32."""
    b, n = x.shape
    seg = n // segments
    return jnp.mean(x.astype(jnp.float32).reshape(b, segments, seg), axis=-1)


def refine_topk_ref(data, norms, rec_dfs, rec_gid, queries,
                    sel_part, sel_lo, sel_hi, k: int):
    """Dense oracle of the streaming fused refine kernel.

    Same contract as ``repro.kernels.refine_topk.refine_topk`` (plan sorted
    by partition id, pads first): gathers the full ``[Q, MP, cap, n]``
    candidate tensor, masks by DFS interval + segment dedupe, and takes a
    flat top-k — the memory-hungry formulation the kernel streams away.
    Returns ``[Q, k]`` squared ED (+inf pads) and gids (−1 pads).
    """
    q = queries.astype(jnp.float32)
    pid = jnp.maximum(sel_part, 0)
    rows = data[pid].astype(jnp.float32)                    # [Q, MP, cap, n]
    d2 = jnp.maximum(
        jnp.sum(q * q, axis=-1)[:, None, None]
        - 2.0 * jnp.einsum("qn,qmcn->qmc", q, rows)
        + norms[pid], 0.0)
    rdfs, rgid = rec_dfs[pid], rec_gid[pid]                 # [Q, MP, cap]

    in_node = (rdfs >= sel_lo[:, :, None]) & (rdfs < sel_hi[:, :, None])
    incl = (rgid >= 0) & in_node & (sel_part >= 0)[:, :, None]
    # earlier same-partition entry covering the record ⇒ duplicate, drop
    same = sel_part[:, None, :] == sel_part[:, :, None]     # [Q, MP, MP']
    earlier = (jnp.arange(sel_part.shape[1])[None, :]
               < jnp.arange(sel_part.shape[1])[:, None])[None]
    cov = (rdfs[:, :, None, :] >= sel_lo[:, None, :, None]) \
        & (rdfs[:, :, None, :] < sel_hi[:, None, :, None])  # [Q, MP, MP', cap]
    dup = jnp.any(cov & (same & earlier)[:, :, :, None], axis=2)
    incl = incl & ~dup

    qn = queries.shape[0]
    flat_d = jnp.where(incl, d2, 3.4e38).reshape(qn, -1)
    flat_g = jnp.where(incl, rgid, -1).reshape(qn, -1)
    if flat_d.shape[-1] < k:
        pad = k - flat_d.shape[-1]
        flat_d = jnp.pad(flat_d, ((0, 0), (0, pad)), constant_values=3.4e38)
        flat_g = jnp.pad(flat_g, ((0, 0), (0, pad)), constant_values=-1)
    neg, idx = jax.lax.top_k(-flat_d, k)
    return -neg, jnp.take_along_axis(flat_g, idx, axis=-1)


def pivot_rank_ref(paa: jnp.ndarray, pivots: jnp.ndarray, m: int) -> jnp.ndarray:
    """Fused pivot distance + top-m prefix extraction.

    paa: [B, w], pivots: [r, w] → [B, m] int32 (ids of m nearest pivots,
    ascending distance, ties toward the lower id).
    """
    paa = paa.astype(jnp.float32)
    pivots = pivots.astype(jnp.float32)
    a2 = jnp.sum(paa * paa, axis=-1, keepdims=True)
    b2 = jnp.sum(pivots * pivots, axis=-1)
    d = jnp.maximum(a2 - 2.0 * (paa @ pivots.T) + b2, 0.0)
    _, idx = jax.lax.top_k(-d, m)
    return idx.astype(jnp.int32)
