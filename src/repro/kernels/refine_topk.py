"""Pallas TPU kernel: streaming fused refine — masked ED + top-k in one pass.

The refine stage (paper §VI) ranks every record of the planner-selected
(partition, trie-node) targets by exact ED and keeps the k best.  The dense
path gathers ``store.data[sel_part]`` into a ``[Q, MP, cap, n]`` tensor,
materialises the ``[Q, MP, cap]`` distance tensor, and runs a separate
top-k — fine on CPU, a memory wall on device once Q and the slot budget
grow (the gather alone is Q×MP×cap×n×4 bytes of HBM traffic and residency).

This kernel streams instead.  Grid = (Q, MP, cap/BLOCK_C); each step DMAs
one ``[BLOCK_C, n]`` candidate block of one query's plan entry straight out
of the partition store in HBM — the entry's partition id is read from the
scalar-prefetched plan (``PrefetchScalarGridSpec``), so there is no
host-side gather at all — and then, entirely in VMEM/registers:

  * computes the block's squared EDs (‖q‖² − 2·q·xᵀ + ‖x‖², MXU matmul);
  * applies the DFS-tag interval mask of the targeting trie node and the
    segment-dedupe predicate (a record already covered by an earlier
    same-partition plan entry is dropped — plan entries arrive sorted by
    partition id, exactly like the dense path's segmented scan) inline;
  * folds the block into a running per-query k-best (distance, gid)
    accumulator held in the revisited ``[1, k]`` output block — an online
    top-k in the FlashAttention style of streaming reductions.

Nothing of shape ``[Q, MP, cap]`` (let alone the gathered rows) ever
exists: the working set per grid step is the BLOCK_C×n candidate tile plus
two k+BLOCK_C merge rows, ≲ BLOCK_C·n·4 bytes ≈ 2 MB at the defaults —
comfortably inside VMEM with double-buffering headroom.

Exactness: per-candidate distances are independent dot products, so
blocking does not change them; the merge extracts minima with a
first-occurrence (= lowest flat index) tie-break, with accumulator entries
ordered before the current block, which reproduces ``jax.lax.top_k`` over
the full flat candidate axis — gids match the dense oracle exactly under
the tie-break rule, distances to fp rounding of the dot.  Slots with fewer
than k candidates keep the +inf/-1 initialisation, which the wrapper maps
to the ``PAD_DIST``/gid=-1 convention — identical to the dense path.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_C = 512
_INF = 3.4e38  # python float: jnp scalars would be captured as consts


def pick_block_c(cap: int) -> int:
    """Trace-time candidate-block size for a store of slot capacity ``cap``.

    First step of the ROADMAP "kernel autotuning" item: instead of a fixed
    ``DEFAULT_BLOCK_C`` the block is ``min(512, next_pow2(cap))`` — small-
    cap stores (fleet deltas, sealed delta shards) stop streaming 512-wide
    blocks that are mostly index-masked padding, while keeping the block a
    power of two (lane-friendly) and a single block whenever the whole
    capacity fits.  Callers pin ``block_c`` explicitly to override.
    """
    return min(DEFAULT_BLOCK_C, 1 << max(int(cap) - 1, 0).bit_length())


def _refine_topk_kernel(sel_ref, q_ref, data_ref, norms_ref, dfs_ref,
                        gid_ref, sp_ref, lo_ref, hi_ref, outd_ref, outg_ref,
                        *, k: int, block_c: int, cap: int, mp: int):
    """One candidate block of one (query, plan-entry) pair.

    ``sel_ref`` is the scalar-prefetched ``[Q, MP]`` partition-id plan (it
    already steered this step's DMA via the index maps); ``sp/lo/hi_ref``
    are the same plan rows in VMEM for the inline mask + dedupe.
    """
    s = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when((s == 0) & (c == 0))
    def _init():
        outd_ref[...] = jnp.full((1, k), _INF, jnp.float32)
        outg_ref[...] = jnp.full((1, k), -1, jnp.int32)

    qv = q_ref[...].astype(jnp.float32)                       # [1, n]
    rows = data_ref[0].astype(jnp.float32)                    # [bc, n]
    q2 = jnp.sum(qv * qv)
    dots = jax.lax.dot_general(qv, rows, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    d2 = jnp.maximum(q2 - 2.0 * dots + norms_ref[...], 0.0)   # [1, bc]

    dfs = dfs_ref[...]                                        # [1, bc]
    gid = gid_ref[...]
    parts, los, his = sp_ref[...], lo_ref[...], hi_ref[...]   # [1, mp]

    # this entry's (partition, interval): one-hot extract at slot s (masked
    # sum instead of a dynamic VMEM index — Mosaic-safe, mp is small)
    iota_mp = jax.lax.broadcasted_iota(jnp.int32, (1, mp), 1)
    onehot = iota_mp == s
    part_s = jnp.sum(jnp.where(onehot, parts, 0))
    lo_s = jnp.sum(jnp.where(onehot, los, 0))
    hi_s = jnp.sum(jnp.where(onehot, his, 0))

    # interval mask; the cap-tail of a ragged last block is masked by index
    cidx = c * block_c + jax.lax.broadcasted_iota(jnp.int32, (1, block_c), 1)
    incl = (gid >= 0) & (dfs >= lo_s) & (dfs < hi_s) & (part_s >= 0) \
        & (cidx < cap)

    # segment dedupe: drop records an earlier same-partition entry covered
    earlier = (iota_mp < s) & (parts == part_s)               # [1, mp]
    dcol = dfs[0][:, None]                                    # [bc, 1]
    covered = jnp.any(earlier & (dcol >= los) & (dcol < his),
                      axis=1)[None, :]                        # [1, bc]
    incl = incl & ~covered

    cand_d = jnp.where(incl, d2, _INF)
    cand_g = jnp.where(incl, gid, -1)

    # online top-k: accumulator first so flat-order tie-breaks are kept
    all_d = jnp.concatenate([outd_ref[...], cand_d], axis=1)  # [1, k+bc]
    all_g = jnp.concatenate([outg_ref[...], cand_g], axis=1)
    idxs = jax.lax.broadcasted_iota(jnp.int32, all_d.shape, 1)
    new_d, new_g = [], []
    for _ in range(k):      # static unroll, k small (same idiom as
        pos = jnp.argmin(all_d[0]).astype(jnp.int32)   # pivot_rank's top-m)
        new_d.append(jnp.min(all_d))
        new_g.append(jnp.sum(jnp.where(idxs == pos, all_g, 0)))
        all_d = jnp.where(idxs == pos, _INF, all_d)
    outd_ref[...] = jnp.stack(new_d)[None, :].astype(jnp.float32)
    outg_ref[...] = jnp.stack(new_g)[None, :].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "block_c", "interpret"))
def refine_topk(data: jnp.ndarray, norms: jnp.ndarray, rec_dfs: jnp.ndarray,
                rec_gid: jnp.ndarray, queries: jnp.ndarray,
                sel_part: jnp.ndarray, sel_lo: jnp.ndarray,
                sel_hi: jnp.ndarray, k: int, *,
                block_c: Optional[int] = None,
                interpret: bool = False):
    """Streaming fused masked-ED + top-k over the partition store.

    Args:
      data / norms / rec_dfs / rec_gid: the partition store columns,
        ``[P, cap, n]`` / ``[P, cap]`` ×3.
      queries: ``[Q, n]``.
      sel_part / sel_lo / sel_hi: ``[Q, MP]`` plan, **sorted by partition
        id along the entry axis** (pads first — the dedupe predicate needs
        same-partition entries contiguous, as in the dense path).
      k: answers per query.
      block_c: candidate-block width; None (default) picks it at trace
        time from the store capacity via :func:`pick_block_c`.  Any value
        is numerically equivalent — blocking never changes the per-record
        distances or the merge order.

    Returns:
      (d2, gid): ``[Q, k]`` ascending **squared** ED (+inf beyond the
      candidate pool) and record ids (−1 there) — callers apply sqrt and
      the sentinel convention.
    """
    qn, n = queries.shape
    mp = sel_part.shape[1]
    cap = data.shape[1]
    if qn == 0 or mp == 0:
        return (jnp.full((qn, k), _INF, jnp.float32),
                jnp.full((qn, k), -1, jnp.int32))
    bc = pick_block_c(cap) if block_c is None \
        else min(block_c, max(cap, 1))
    nblocks = pl.cdiv(cap, bc)

    store_block = lambda q, s, c, sel: (jnp.maximum(sel[q, s], 0), c)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(qn, mp, nblocks),
        in_specs=[
            pl.BlockSpec((1, n), lambda q, s, c, sel: (q, 0)),
            pl.BlockSpec((1, bc, n),
                         lambda q, s, c, sel: (jnp.maximum(sel[q, s], 0),
                                               c, 0)),
            pl.BlockSpec((1, bc), store_block),
            pl.BlockSpec((1, bc), store_block),
            pl.BlockSpec((1, bc), store_block),
            pl.BlockSpec((1, mp), lambda q, s, c, sel: (q, 0)),
            pl.BlockSpec((1, mp), lambda q, s, c, sel: (q, 0)),
            pl.BlockSpec((1, mp), lambda q, s, c, sel: (q, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda q, s, c, sel: (q, 0)),
            pl.BlockSpec((1, k), lambda q, s, c, sel: (q, 0)),
        ],
    )
    return pl.pallas_call(
        functools.partial(_refine_topk_kernel, k=k, block_c=bc, cap=cap,
                          mp=mp),
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((qn, k), jnp.float32),
                   jax.ShapeDtypeStruct((qn, k), jnp.int32)],
        interpret=interpret,
    )(sel_part, queries, data, norms, rec_dfs, rec_gid,
      sel_part, sel_lo, sel_hi)
