"""Pallas TPU kernel: PAA segmentation (memory-bound mean-pool).

PAA over a billion-series repository is a pure streaming reduce: every raw
series byte is read exactly once and n/w-reduced.  The kernel tiles the batch
dimension so each VMEM block holds BLOCK_B raw series ([BLOCK_B, n] fp32) and
emits [BLOCK_B, w]; the reshape-reduce happens in registers.  Roofline-wise
this op sits on the HBM-bandwidth line — the kernel's job is simply to not
lose to it (no extra passes, no transposes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 256


def _paa_kernel(x_ref, out_ref, *, segments: int):
    x = x_ref[...].astype(jnp.float32)            # [bb, n]
    bb, n = x.shape
    seg = n // segments
    out_ref[...] = jnp.mean(x.reshape(bb, segments, seg), axis=-1)


@functools.partial(jax.jit, static_argnames=("segments", "block_b", "interpret"))
def paa(x: jnp.ndarray, segments: int, *,
        block_b: int = DEFAULT_BLOCK_B,
        interpret: bool = False) -> jnp.ndarray:
    """PAA: ``[B, n]`` → ``[B, w]`` float32 (n divisible by w)."""
    b, n = x.shape
    if n % segments:
        raise ValueError(f"series length {n} not divisible by w={segments}")
    bb = min(block_b, max(b, 1))
    b_pad = (-b) % bb
    if b_pad:
        x = jnp.pad(x, ((0, b_pad), (0, 0)))
    gb = x.shape[0] // bb

    out = pl.pallas_call(
        functools.partial(_paa_kernel, segments=segments),
        grid=(gb,),
        in_specs=[pl.BlockSpec((bb, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((bb, segments), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], segments), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:b]
