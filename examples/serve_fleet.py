"""Index-fleet serving example: shards + streaming ingest + lifecycle.

    PYTHONPATH=src python examples/serve_fleet.py [--shards 3] [--mesh]
                                                  [--storage DIR] [--metrics]

Builds a fleet of per-tenant CLIMBER shards, serves a request queue through
one FleetEngine (signature routing fans each query out to a shard subset),
streams fresh records into the delta shard, seals it with ``compact()``
(the INX rebuild runs on the compactor worker thread), and shows that the
answers on the same contents are unchanged.

``--mesh`` attaches a data-axis mesh over every local device, so sealed
shards execute mesh-resident (one shard_map fan-out instead of the
per-shard host loop) — and the example asserts the two placements return
bit-identical answers.

``--storage DIR`` (default: a temp dir) attaches the lifecycle plane's
durable storage: inserts append to the write-ahead log before the delta
scatter, ``save()`` snapshots the sealed shards, and the example simulates
a crash — ``IndexFleet.open`` replays the WAL tail and the restored
answers are asserted bit-identical.  Step-by-step commentary:
docs/SERVING.md.
"""
import argparse
import tempfile

import jax
import numpy as np

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.launch.mesh import make_mesh
from repro.serve import api
from repro.utils.config import ClimberConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=3)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--mesh", action="store_true",
                    help="lay sealed shards out over the local devices and "
                         "serve via the single-shard_map mesh placement")
    ap.add_argument("--storage", default=None,
                    help="durable storage dir (WAL + shard snapshots); "
                         "default: a fresh temp dir")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the Prometheus text exposition page of the "
                         "process metrics registry (repro.obs) at exit")
    args = ap.parse_args()
    storage = args.storage or tempfile.mkdtemp(prefix="fleet-storage-")

    cfg = ClimberConfig(series_len=128, paa_segments=16, num_pivots=64,
                        prefix_len=8, capacity=256, sample_frac=0.2,
                        max_centroids=32, k=10, candidate_groups=4,
                        adaptive_factor=4)
    per = 2_000
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   per * args.shards, 128))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2), data,
                                      args.requests))

    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   delta_capacity=2_048, auto_compact=False),
                       storage_dir=storage)
    for s in range(args.shards):
        fleet.add_shard(f"tenant{s}", data[s * per:(s + 1) * per])
    if args.mesh:
        mesh = make_mesh((jax.device_count(),), ("data",))
        fleet.attach_mesh(mesh)     # queries now default to placement="mesh"
    print(f"fleet: {len(fleet.shards)} shards, {fleet.total_records} "
          f"records, placement="
          f"{'mesh (%d devices)' % jax.device_count() if args.mesh else 'host'}")

    # serve a queue through one engine over the whole fleet
    engine = FleetEngine(fleet, config=api.ServingConfig(
        batch_size=args.batch_size, k=10, routing="signature"))
    tickets = [engine.submit_request(
        api.QueryRequest(series=queries[i], request_id=i))
        for i in range(args.requests)]
    engine.run_until_drained()
    r0 = tickets[0].result
    print(f"req 0: top-3 gids={r0.gid[:3].tolist()} "
          f"parts={r0.partitions_touched} latency={r0.latency_ms:.1f}ms")

    # streaming ingest: fresh records are visible immediately
    fresh = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(9),
                                    512, 128))
    gids = fleet.insert(fresh)
    d, g, _ = fleet.query(fresh[:1], 5, routing="exhaustive")
    print(f"inserted {len(gids)} records (delta occupancy "
          f"{fleet.delta.occupancy}); self-query hit gid {g[0, 0]} "
          f"(expected {gids[0]}) at d={d[0, 0]:.4f}")

    # mesh fan-out is bit-identical to the host-loop oracle
    if args.mesh:
        dh, gh, _ = fleet.query(queries, 10, placement="host")
        dm, gm, _ = fleet.query(queries, 10, placement="mesh")
        assert np.array_equal(gh, gm) and np.array_equal(dh, dm)
        print("mesh placement: one shard_map fan-out, answers bit-identical "
              "to the host loop")

    # restart durability: "crash" the process state and replay the WAL —
    # the delta was never snapshotted, yet answers come back bit-identical
    fleet.save()
    d1, g1, _ = fleet.query(queries, 10, routing="exhaustive",
                            variant="exhaustive")
    restored = IndexFleet.open(storage)
    dr, gr, _ = restored.query(queries, 10, routing="exhaustive",
                               variant="exhaustive")
    assert np.array_equal(g1, gr) and np.array_equal(d1, dr)
    print(f"restart: WAL tail replayed "
          f"({restored.delta.occupancy} delta records), answers "
          f"bit-identical")

    # compaction seals the delta on the worker thread; answers on the same
    # contents don't move, and the WAL segment is truncated once the shard
    # snapshot is durable
    fleet.compact()
    d2, g2, _ = fleet.query(queries, 10, routing="exhaustive",
                            variant="exhaustive")
    assert np.array_equal(g1, g2) and np.array_equal(d1, d2)
    print(f"compact(): sealed into {fleet.shards[-1].key}; "
          f"answers unchanged")

    precision = fleet.audit_routing(queries, 10)
    s = fleet.stats
    life = s.lifecycle_snapshot()
    print(f"OK — {s.queries} fleet queries, routing precision "
          f"{precision:.3f}, fan-out savings {s.fanout_savings:.0%}, "
          f"per-shard load {s.per_shard_queries}")
    print(f"lifecycle — compaction {life['compaction_ms']:.0f}ms total, "
          f"pending WAL {life['wal_bytes']} bytes, "
          f"{life['merges']} merges, {life['retired_shards']} retired "
          f"(storage: {storage})")

    if args.metrics:
        # run one short network-plane segment so the page includes the
        # per-connection net.* counters and the client rtt histogram next
        # to the span / engine metrics; the online recall sentinel
        # shadow-samples the served queries and audits them off-path so
        # the fleet.online_recall gauge is live on the page
        from repro.obs import RecallSentinel
        from repro.serve.net import ClimberClient, serve_in_thread
        sentinel = RecallSentinel(fleet, sample_rate=1.0)
        server, stop = serve_in_thread(engine)
        with ClimberClient("127.0.0.1", server.port) as client:
            client.query_batch(list(queries[:4]), k=10)
            sentinel.drain()
            # fetch the page over the admin plane — the same socket the
            # queries rode — exactly what a scrape sidecar would do
            page = client.metrics()
            health = client.health()
        stop()
        print(f"admin health: ready={health['ready']} "
              f"shards={health['shards']} pending={health['pending']} "
              f"spans_dropped={health['spans_dropped']}")
        print(f"sentinel: online recall "
              f"{sentinel.online_recall:.3f} over "
              f"{sentinel.snapshot()['audits']} audits")
        # everything above recorded into the process registry: spans into
        # span.* histograms, fleet/engine counters via collectors, the net
        # segment into net.*, the sentinel's gauge — this is the page a
        # Prometheus scrape of the process would return
        print("\n# --- metrics (Prometheus text exposition) ---")
        print(page, end="")


if __name__ == "__main__":
    main()
