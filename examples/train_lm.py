"""End-to-end training driver example: train a ~1M-param smoke-config model
for a few hundred steps with checkpoint/resume and fault-tolerant stepping.

    PYTHONPATH=src python examples/train_lm.py [--arch mamba2-780m] [--steps 200]

Uses the same `repro.launch.train` driver the fleet launcher uses —
deterministic data pipeline, AdamW, atomic checkpoints, watchdog recovery.
"""
import argparse
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    with tempfile.TemporaryDirectory() as ckpt:
        _, losses = train(args.arch, smoke=True, steps=args.steps,
                          batch=args.batch, seq=args.seq, ckpt_dir=ckpt,
                          checkpoint_every=50, lr=1e-3, kv_chunk=64,
                          data_mode="periodic")
        k = max(len(losses) // 5, 1)
        head, tail = (sum(losses[:k]) / k, sum(losses[-k:]) / k)
        print(f"loss: {head:.3f} → {tail:.3f} over {len(losses)} steps")
        assert tail < head, "training must reduce loss on learnable data"
        print("OK")


if __name__ == "__main__":
    main()
