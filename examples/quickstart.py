"""Quickstart: build a CLIMBER index over data series and run kNN queries.

    PYTHONPATH=src python examples/quickstart.py

Covers the paper's full pipeline at laptop scale: synthetic RandomWalk data
→ CLIMBER-INX construction (PAA → P⁴ dual signatures → groups → trie
partitions) → CLIMBER-kNN-Adaptive queries → recall against the exact scan.
"""
import jax
import numpy as np

from repro.baselines import exact_knn, recall
from repro.core import build_index, knn_query
from repro.data import make_dataset, make_queries
from repro.utils.config import ClimberConfig


def main():
    cfg = ClimberConfig(
        series_len=256,        # n  — raw readings per series
        paa_segments=16,       # w  — PAA word length
        num_pivots=96,         # r  — pivots (paper default is 200 at TB scale)
        prefix_len=10,         # m  — pivot-permutation-prefix length
        capacity=256,          # c  — partition capacity (HDFS-block analogue)
        sample_frac=0.15,      # α  — skeleton sample
        k=50,
        adaptive_factor=4,     # CLIMBER-kNN-Adaptive-4X (paper default)
        candidate_groups=8,
    )

    print("generating 20k RandomWalk series ...")
    data = make_dataset("randomwalk", jax.random.PRNGKey(0), 20_000, 256)
    queries = make_queries(jax.random.PRNGKey(1), data, 16)

    print("building CLIMBER-INX ...")
    index = build_index(jax.random.PRNGKey(2), data, cfg)
    print(f"  groups={index.num_groups} partitions="
          f"{index.forest.num_partitions} trie_nodes={index.forest.num_nodes}")

    print("running CLIMBER-kNN-Adaptive ...")
    dist, gid, plan = knn_query(index, queries, 50, variant="adaptive")

    _, exact_ids = exact_knn(queries, data, 50)
    r = recall(np.asarray(gid), np.asarray(exact_ids))
    touched = float(np.asarray(plan.partitions_touched()).mean())
    frac = touched * index.store.capacity / data.shape[0]
    print(f"  recall@50 = {r:.3f}   partitions touched = {touched:.1f} "
          f"(~{frac:.1%} of the data)")
    assert r > 0.3, "recall sanity floor"
    print("OK")


if __name__ == "__main__":
    main()
