"""kNN-LM: CLIMBER as the retrieval plane for a language model.

    PYTHONPATH=src python examples/knn_lm.py

This is the integration the framework is built around (DESIGN.md §3): the
model plane produces hidden-state embeddings; CLIMBER indexes a datastore of
(embedding → next token) pairs; at inference the model's next-token
distribution is interpolated with the distribution of retrieved neighbours
(Khandelwal et al., kNN-LM).  Every piece is the production path: the Model
zoo forward, CLIMBER-INX build, CLIMBER-kNN-Adaptive query.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import build_index, knn_query
from repro.data.tokens import TokenPipeline
from repro.models import Model
from repro.utils.config import ClimberConfig


def main():
    cfg = get_config("internlm2-1.8b", smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    pipe = TokenPipeline(cfg, global_batch=32, seq_len=32, seed=0)

    # ---- build the datastore: (hidden state at t) -> token at t+1 --------
    print("building datastore from model hidden states ...")
    fwd = jax.jit(lambda p, b: model.forward(p, b, kv_chunk=32))
    embeddings, next_tokens = [], []
    for step in range(8):
        batch = pipe.batch_at(step)
        tokens = batch["tokens"][:, :-1]
        logits = fwd(params, {"tokens": tokens})
        # hidden-state stand-in: pre-softmax logits projected is costly; use
        # the model's embedding of the context via a stop-grad logit probe
        hidden = logits[..., : cfg.d_model]          # [B, S, d] proxy probe
        embeddings.append(np.asarray(hidden[:, :-1].reshape(-1, cfg.d_model),
                                     np.float32))
        next_tokens.append(np.asarray(tokens[:, 1:].reshape(-1)))
    datastore = np.concatenate(embeddings)           # [N, d]
    labels = np.concatenate(next_tokens)             # [N]
    print(f"  datastore: {datastore.shape[0]} entries, d={cfg.d_model}")

    # ---- index it with CLIMBER ------------------------------------------
    ccfg = ClimberConfig(series_len=cfg.d_model, paa_segments=16,
                         num_pivots=48, prefix_len=6, capacity=256,
                         sample_frac=0.25, max_centroids=24, k=16,
                         candidate_groups=4, adaptive_factor=4)
    index = build_index(jax.random.PRNGKey(1), jnp.asarray(datastore), ccfg)
    print(f"  CLIMBER index: {index.num_groups} groups, "
          f"{index.forest.num_partitions} partitions")

    # ---- interpolated next-token prediction ------------------------------
    batch = pipe.batch_at(99)
    ctx = batch["tokens"][:4, :16]
    logits = fwd(params, {"tokens": ctx})
    query_emb = logits[:, -1, : cfg.d_model]         # [4, d]
    dist, gid, _ = knn_query(index, query_emb, 16, variant="adaptive")

    lam, temp = 0.25, 1.0
    p_lm = jax.nn.softmax(logits[:, -1].astype(jnp.float32), axis=-1)
    p_out = []
    for i in range(4):
        valid = np.asarray(gid[i]) >= 0
        knn_probs = np.zeros(cfg.vocab_size, np.float32)
        if valid.any():
            w = np.exp(-np.asarray(dist[i])[valid] / temp)
            w = w / w.sum()
            for wj, g in zip(w, np.asarray(gid[i])[valid]):
                knn_probs[labels[g]] += wj
        mix = (1 - lam) * np.asarray(p_lm[i]) + lam * knn_probs
        p_out.append(mix)
        print(f"  query {i}: retrieved {valid.sum()} neighbours; "
              f"argmax LM={int(np.asarray(p_lm[i]).argmax())} "
              f"mixed={int(mix.argmax())}")
    assert all(abs(p.sum() - 1) < 1e-3 for p in p_out)
    print("OK")


if __name__ == "__main__":
    main()
