"""Network serving example: typed client/server over a fleet.

    PYTHONPATH=src python examples/serve_net.py [--shards 2] [--self-test]
                                                [--routing signature]
                                                [--metrics]

Starts the asyncio :class:`~repro.serve.net.ClimberServer` on a loopback
socket in front of one :class:`~repro.fleet.FleetEngine`, then talks to it
with :class:`~repro.serve.net.ClimberClient`: handshake (``ServerInfo``),
single round trips, a pipelined batch that keeps the double-buffered
admission full, and typed refusals (wrong series shape → ``BAD_REQUEST``).
The example asserts the answers that crossed the socket are bit-identical
to calling ``IndexFleet.query`` directly — the wire adds zero numeric
difference.

``--self-test`` runs the same flow on both routing modes plus an overlap
check (batch N+1 admitted while tick N executes) and exits non-zero on
any mismatch — the localhost smoke the `net` CI job runs.

``--metrics`` dumps the Prometheus page at exit: the net plane's
per-connection ``net.frames_in``/``net.frames_out`` counters and the
client's ``net.rtt_ms`` histogram sit next to the engine's
``serve.latency_ms``.
"""
import argparse
import sys
import time

import jax
import numpy as np

from repro.data import make_dataset, make_queries
from repro.fleet import FleetConfig, FleetEngine, IndexFleet
from repro.serve import api
from repro.serve.net import ClimberClient, ServerError, serve_in_thread
from repro.utils.config import ClimberConfig


def build_fleet(shards: int):
    cfg = ClimberConfig(series_len=128, paa_segments=16, num_pivots=64,
                        prefix_len=8, capacity=256, sample_frac=0.2,
                        max_centroids=32, k=10, candidate_groups=4,
                        adaptive_factor=4)
    per = 1_500
    data = np.asarray(make_dataset("randomwalk", jax.random.PRNGKey(0),
                                   per * shards, 128))
    queries = np.asarray(make_queries(jax.random.PRNGKey(2), data, 12))
    fleet = IndexFleet(FleetConfig(shard_cfg=cfg, fanout=2,
                                   delta_capacity=2_048, auto_compact=False))
    for s in range(shards):
        fleet.add_shard(f"tenant{s}", data[s * per:(s + 1) * per])
    return fleet, queries


def run_mode(fleet, queries, routing: str, batch_size: int) -> bool:
    variant = "exhaustive" if routing == "exhaustive" else "adaptive"
    engine = FleetEngine(fleet, config=api.ServingConfig(
        batch_size=batch_size, k=10, routing=routing, variant=variant))
    server, stop = serve_in_thread(engine)
    try:
        with ClimberClient("127.0.0.1", server.port) as client:
            info = client.info
            print(f"[{routing}] connected to 127.0.0.1:{server.port} — "
                  f"engine={info.engine} shards={info.shards} "
                  f"series_len={info.series_len} k_max={info.k_max} "
                  f"wire v{info.wire_version}")

            res = client.query(queries[0], k=10)
            print(f"[{routing}] one round trip: top-3 gids="
                  f"{res.gid[:3].tolist()} parts={res.partitions_touched} "
                  f"server latency {res.latency_ms:.1f}ms")

            try:
                client.query(np.zeros(13, np.float32))
            except ServerError as exc:
                print(f"[{routing}] typed refusal: {exc.code} "
                      f"({exc.reply.message})")

            t0 = time.perf_counter()
            got = client.query_batch(list(queries), k=10)
            wall = (time.perf_counter() - t0) * 1e3
            print(f"[{routing}] pipelined {len(got)} queries in "
                  f"{wall:.0f}ms wall; overlapped admissions so far: "
                  f"{server.overlap_admissions}")
    finally:
        stop()

    dist, gid, _ = fleet.query(queries, 10, routing=routing,
                               variant=variant)
    same = np.array_equal(np.stack([r.gid for r in got]), gid) and \
        np.array_equal(np.stack([r.dist for r in got]),
                       dist.astype(np.float32))
    print(f"[{routing}] socket answers bit-identical to direct "
          f"fleet.query: {same}")
    return same and server.overlap_admissions > 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, default=2)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--routing", default="signature",
                    choices=["signature", "exhaustive"])
    ap.add_argument("--self-test", action="store_true",
                    help="run both routing modes, assert bit-identity and "
                         "admission overlap, exit non-zero on failure")
    ap.add_argument("--metrics", action="store_true",
                    help="dump the Prometheus page (net.* + serve.*) at exit")
    args = ap.parse_args()

    fleet, queries = build_fleet(args.shards)
    print(f"fleet: {len(fleet.shards)} shards, {fleet.total_records} records")

    modes = ["signature", "exhaustive"] if args.self_test else [args.routing]
    ok = all([run_mode(fleet, queries, m, args.batch_size) for m in modes])

    if args.metrics:
        from repro.obs import REGISTRY, to_prometheus
        print("\n# --- metrics (Prometheus text exposition) ---")
        print(to_prometheus(REGISTRY), end="")

    if args.self_test:
        print("self-test:", "OK" if ok else "FAILED")
        sys.exit(0 if ok else 1)


if __name__ == "__main__":
    main()
