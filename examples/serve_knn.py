"""Batched kNN serving example: the retrieval-plane engine loop.

    PYTHONPATH=src python examples/serve_knn.py [--batch-size 8]

Builds a small CLIMBER index, submits requests to the ClimberEngine queue,
drains it, and prints per-query metrics plus aggregate queries/sec.
"""
import argparse

import jax
import numpy as np

from repro.core import build_index
from repro.data import make_dataset, make_queries
from repro.serve import ClimberEngine, api
from repro.utils.config import ClimberConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--variant", default="adaptive")
    args = ap.parse_args()

    cfg = ClimberConfig(series_len=128, paa_segments=16, num_pivots=64,
                        prefix_len=8, capacity=256, sample_frac=0.2,
                        max_centroids=32, k=10, candidate_groups=4,
                        adaptive_factor=4)
    data = make_dataset("randomwalk", jax.random.PRNGKey(0), 8000, 128)
    index = build_index(jax.random.PRNGKey(1), data, cfg)
    queries = np.asarray(make_queries(jax.random.PRNGKey(2), data,
                                      args.requests))

    engine = ClimberEngine(index, config=api.ServingConfig(
        batch_size=args.batch_size, variant=args.variant, k=10))
    tickets = [engine.submit_request(
        api.QueryRequest(series=queries[i], request_id=i))
        for i in range(args.requests)]
    engine.run_until_drained()

    for t in tickets[:4]:
        r = t.result
        print(f"req {r.request_id}: top-3 gids={r.gid[:3].tolist()} "
              f"parts={r.partitions_touched} cands={r.candidates_scanned} "
              f"latency={r.latency_ms:.1f}ms fill={r.batch_fill:.2f}")
    s = engine.stats
    assert all(t.ok for t in tickets)
    print(f"OK — {s.queries} queries in {s.ticks} ticks: "
          f"{s.queries_per_sec:.1f} q/s, "
          f"mean parts={s.mean_partitions_touched:.2f}, "
          f"mean cands={s.mean_candidates_scanned:.0f}")


if __name__ == "__main__":
    main()
