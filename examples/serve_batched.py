"""Batched serving example: continuous prefill+decode over request slots.

    PYTHONPATH=src python examples/serve_batched.py [--arch zamba2-2.7b]

Drives the vLLM-shaped engine (repro.serve.engine) with a smoke-config model:
8 requests through 4 slots, one decode tick for all live slots per step.
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Model
from repro.serve import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    engine = Engine(model, params, slots=4, max_len=64)

    rng = np.random.default_rng(0)
    reqs = []
    for rid in range(args.requests):
        req = Request(rid=rid,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          rng.integers(4, 12),
                                          dtype=np.int32),
                      max_new_tokens=12)
        reqs.append(req)
        engine.submit(req)

    engine.run_until_drained(max_ticks=400)
    for req in reqs:
        assert req.done and len(req.generated) >= 12
        print(f"req {req.rid}: prompt_len={len(req.prompt)} "
              f"generated={req.generated[:8]}...")
    print("OK — all requests served")


if __name__ == "__main__":
    main()
